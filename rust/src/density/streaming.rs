//! Streaming shadow density estimation — the online-learning extension
//! the paper's introduction motivates (visual tracking, online KMLAs).
//!
//! Algorithm 2 is a greedy ε-cover, which admits a natural one-pass
//! streaming form: for each arriving point, absorb it into the first
//! existing center within ε (incrementing that center's weight) or
//! promote it to a new center.  On a fixed dataset, processing points in
//! order reproduces batch Algorithm 2 *exactly* (same centers, same
//! weights) — see the equivalence test — while supporting unbounded
//! streams with O(m) state and O(m) work per point.
//!
//! `merge` combines two streaming estimators (e.g. from shards): centers
//! of one are re-streamed into the other carrying their weights, which
//! preserves total mass and the ε-separation invariant.
//!
//! ## Deltas (the online-lifecycle feed)
//!
//! Consumers that maintain derived state (the incremental RSKPCA trainer
//! in `kpca::trainer`) do not want to rescan the whole cover after every
//! point.  [`StreamingShadow::drain_delta`] reports exactly what changed
//! since the previous drain as a [`ShadowDelta`]: center rows **added**,
//! positions **removed** (decay-driven expiry), how many weight **bumps**
//! occurred, plus the full current weight vector.  Replaying `removed`
//! (descending) then appending `added` onto the previously drained
//! center list reproduces the streamer's current center ordering exactly
//! — the contract `kpca::GramCache::apply_delta` relies on.
//!
//! ## Decay (drift adaptation)
//!
//! [`StreamingShadow::with_decay`] turns on exponential forgetting: every
//! observation multiplies all existing mass by `decay`, and centers whose
//! effective weight falls below `floor` are expired at the next drain.
//! Snapshots renormalize the surviving mass to `n_seen` so the
//! [`ReducedSet`] weight invariant (`Σw = n_source`) keeps holding and
//! the density-weighted eigenproblem sees a proper probability vector.

use std::collections::HashSet;

use super::ReducedSet;
use crate::kernel::Kernel;
use crate::linalg::{sq_euclidean, Matrix};

/// Raw-mass scale at which decayed weights are renormalized in place to
/// avoid overflow of the shared boost factor.
const BOOST_RENORM: f64 = 1e12;

/// What changed in a [`StreamingShadow`] since the previous
/// [`StreamingShadow::drain_delta`] call.
///
/// Replay contract: starting from the previously drained center list,
/// remove the positions in `removed` (highest first), then append the
/// rows of `added` — the result is the streamer's current center list,
/// in order, and `weights[i]` belongs to center `i` of that list.
#[derive(Clone, Debug)]
pub struct ShadowDelta {
    /// Positions (into the *previously drained* center ordering) of
    /// centers that were expired by decay, ascending.
    pub removed: Vec<usize>,
    /// Center rows promoted since the last drain, in promotion order;
    /// appended after the removals are applied.
    pub added: Matrix,
    /// Full current weight vector (normalized so `Σw = n_source`),
    /// aligned with the post-replay center ordering.
    pub weights: Vec<f64>,
    /// Normalization count for `weights` (the points observed so far).
    pub n_source: usize,
    /// Number of absorb-into-existing-center events since the last drain
    /// (weight-only changes; zero together with empty `removed`/`added`
    /// means the window saw no observations).
    pub bumped: usize,
}

impl ShadowDelta {
    /// Did the center *set* change (rows added or removed)?
    pub fn is_structural(&self) -> bool {
        !self.removed.is_empty() || self.added.rows() > 0
    }

    /// Did nothing at all change since the last drain?
    pub fn is_empty(&self) -> bool {
        !self.is_structural() && self.bumped == 0
    }
}

/// Online shadow-set selector with O(m) state.
#[derive(Clone, Debug)]
pub struct StreamingShadow {
    ell: f64,
    eps2: f64,
    dim: usize,
    /// Flattened center rows (m x dim).
    centers: Vec<f64>,
    /// Raw mass per center; effective weight = raw / `boost`.
    weights: Vec<f64>,
    /// Stable per-center ids (never reused) for delta bookkeeping.
    ids: Vec<u64>,
    next_id: u64,
    n_seen: usize,
    /// Per-observation retention factor; 1.0 = no forgetting.
    decay: f64,
    /// Effective-weight floor below which a decayed center expires.
    prune_below: f64,
    /// Shared inflation factor: raw mass recorded at time t is
    /// `weight * decay^-t`, so old mass decays without O(m) rescans.
    boost: f64,
    /// Center ids as of the last `drain_delta` call, in drained order.
    baseline: Vec<u64>,
    /// Weight-bump events since the last drain.
    bumped: usize,
}

impl StreamingShadow {
    /// Create a selector for a fixed kernel bandwidth and ℓ.
    pub fn new(kernel: &Kernel, ell: f64, dim: usize) -> Self {
        let eps = kernel.shadow_radius(ell);
        StreamingShadow {
            ell,
            eps2: eps * eps,
            dim,
            centers: Vec::new(),
            weights: Vec::new(),
            ids: Vec::new(),
            next_id: 0,
            n_seen: 0,
            decay: 1.0,
            prune_below: 0.0,
            boost: 1.0,
            baseline: Vec::new(),
            bumped: 0,
        }
    }

    /// Enable exponential forgetting: each observation scales all
    /// existing mass by `decay` (in `(0, 1]`; 1.0 disables), and centers
    /// whose effective weight drops below `floor` are expired at the
    /// next [`StreamingShadow::drain_delta`].
    pub fn with_decay(mut self, decay: f64, floor: f64) -> Self {
        assert!(
            decay > 0.0 && decay <= 1.0,
            "decay must be in (0, 1], got {decay}"
        );
        assert!(floor >= 0.0, "prune floor must be non-negative");
        self.decay = decay;
        self.prune_below = floor;
        self
    }

    /// Number of retained centers so far.
    pub fn m(&self) -> usize {
        self.weights.len()
    }

    /// Points observed so far.
    pub fn n_seen(&self) -> usize {
        self.n_seen
    }

    /// Observe one point: absorb or promote.  Returns the index of the
    /// center that absorbed it (which may be brand new; the index is
    /// only stable until the next decay-driven expiry).
    pub fn observe(&mut self, x: &[f64]) -> usize {
        self.observe_weighted(x, 1.0)
    }

    /// Observe a point carrying `weight` units of mass (used by `merge`).
    pub fn observe_weighted(&mut self, x: &[f64], weight: f64) -> usize {
        assert_eq!(x.len(), self.dim, "dimension mismatch");
        assert!(weight > 0.0);
        self.n_seen += weight.round() as usize;
        if self.decay < 1.0 {
            self.boost /= self.decay;
            if self.boost > BOOST_RENORM {
                let b = self.boost;
                for w in &mut self.weights {
                    *w /= b;
                }
                self.boost = 1.0;
            }
        }
        let raw = weight * self.boost;
        for j in 0..self.m() {
            let c = &self.centers[j * self.dim..(j + 1) * self.dim];
            if sq_euclidean(c, x) < self.eps2 {
                self.weights[j] += raw;
                self.bumped += 1;
                return j;
            }
        }
        self.centers.extend_from_slice(x);
        self.weights.push(raw);
        self.ids.push(self.next_id);
        self.next_id += 1;
        self.m() - 1
    }

    /// Fold another selector's centers into this one (shard merge).
    /// Total mass is preserved; the result still satisfies the cover
    /// radius 2ε (a merged point sits within ε of its shard center, which
    /// sits within ε of the surviving center).  Intended for non-decayed
    /// shards; with decay active the merged mass arrives as fresh mass.
    pub fn merge(&mut self, other: &StreamingShadow) {
        assert_eq!(self.dim, other.dim);
        for j in 0..other.m() {
            let c = &other.centers[j * other.dim..(j + 1) * other.dim];
            self.observe_weighted(c, other.weights[j] / other.boost);
        }
    }

    /// Current weights normalized so they sum to `n_seen` (exact raw
    /// counts when decay is off, so the batch-equivalence guarantee is
    /// preserved bit for bit).
    fn normalized_weights(&self) -> Vec<f64> {
        if self.decay >= 1.0 {
            return self.weights.clone();
        }
        let total: f64 = self.weights.iter().sum();
        if total <= 0.0 {
            return self.weights.clone();
        }
        let scale = self.n_seen.max(1) as f64 / total;
        self.weights.iter().map(|&w| w * scale).collect()
    }

    /// Expire decayed centers (effective weight below the floor).
    fn prune_expired(&mut self) {
        if self.decay >= 1.0 || self.prune_below <= 0.0 {
            return;
        }
        let raw_floor = self.prune_below * self.boost;
        if self.weights.iter().all(|&w| w >= raw_floor) {
            return;
        }
        let mut keep = 0usize;
        for j in 0..self.m() {
            if self.weights[j] >= raw_floor {
                if keep != j {
                    self.weights[keep] = self.weights[j];
                    self.ids[keep] = self.ids[j];
                    let (dst, src) = (keep * self.dim, j * self.dim);
                    for k in 0..self.dim {
                        self.centers[dst + k] = self.centers[src + k];
                    }
                }
                keep += 1;
            }
        }
        self.weights.truncate(keep);
        self.ids.truncate(keep);
        self.centers.truncate(keep * self.dim);
    }

    /// Report everything that changed since the previous drain (expiring
    /// decayed centers first) and reset the change log.  See
    /// [`ShadowDelta`] for the replay contract.
    pub fn drain_delta(&mut self) -> ShadowDelta {
        self.prune_expired();
        let current: HashSet<u64> = self.ids.iter().copied().collect();
        let previous: HashSet<u64> = self.baseline.iter().copied().collect();
        let removed: Vec<usize> = self
            .baseline
            .iter()
            .enumerate()
            .filter(|(_, id)| !current.contains(*id))
            .map(|(pos, _)| pos)
            .collect();
        let added_idx: Vec<usize> = (0..self.m())
            .filter(|&j| !previous.contains(&self.ids[j]))
            .collect();
        let mut added = Matrix::zeros(added_idx.len(), self.dim);
        for (r, &j) in added_idx.iter().enumerate() {
            added
                .row_mut(r)
                .copy_from_slice(&self.centers[j * self.dim..(j + 1) * self.dim]);
        }
        let delta = ShadowDelta {
            removed,
            added,
            weights: self.normalized_weights(),
            n_source: self.n_seen.max(1),
            bumped: self.bumped,
        };
        self.baseline = self.ids.clone();
        self.bumped = 0;
        delta
    }

    /// Snapshot the current reduced set.
    pub fn snapshot(&self) -> ReducedSet {
        let m = self.m();
        let centers =
            Matrix::from_vec(m, self.dim, self.centers.clone())
                .expect("internal shape");
        ReducedSet {
            centers,
            weights: self.normalized_weights(),
            n_source: self.n_seen.max(1),
            assignment: None,
            method: format!("streaming-shde(ell={})", self.ell),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_mixture_2d;
    use crate::density::{RsdeEstimator, ShadowDensity};
    use crate::kpca::fit_rskpca;

    #[test]
    fn streaming_equals_batch_on_fixed_data() {
        let ds = gaussian_mixture_2d(300, 3, 0.4, 1);
        let kernel = Kernel::gaussian(1.0);
        let batch = ShadowDensity::new(4.0).reduce(&ds.x, &kernel);
        let mut stream = StreamingShadow::new(&kernel, 4.0, 2);
        for i in 0..ds.n() {
            stream.observe(ds.x.row(i));
        }
        let snap = stream.snapshot();
        assert_eq!(snap.m(), batch.m());
        assert_eq!(snap.weights, batch.weights);
        for j in 0..batch.m() {
            assert_eq!(snap.centers.row(j), batch.centers.row(j));
        }
    }

    #[test]
    fn state_is_o_of_m_not_n() {
        let ds = gaussian_mixture_2d(2000, 3, 0.2, 2);
        let kernel = Kernel::gaussian(1.5);
        let mut stream = StreamingShadow::new(&kernel, 3.0, 2);
        for i in 0..ds.n() {
            stream.observe(ds.x.row(i));
        }
        assert_eq!(stream.n_seen(), 2000);
        assert!(stream.m() < 200, "m = {}", stream.m());
        let snap = stream.snapshot();
        assert!(snap.check_invariants());
    }

    #[test]
    fn snapshot_feeds_rskpca_incrementally() {
        // The online use case: keep fitting RSKPCA from snapshots as data
        // streams in; eigenvalues must stabilize.
        let ds = gaussian_mixture_2d(600, 3, 0.4, 3);
        let kernel = Kernel::gaussian(1.0);
        let mut stream = StreamingShadow::new(&kernel, 4.0, 2);
        let mut lambda_trajectory = Vec::new();
        for i in 0..ds.n() {
            stream.observe(ds.x.row(i));
            if (i + 1) % 200 == 0 {
                let model =
                    fit_rskpca(&stream.snapshot(), &kernel, 2).unwrap();
                lambda_trajectory.push(model.op_eigenvalues[0]);
            }
        }
        assert_eq!(lambda_trajectory.len(), 3);
        let last = lambda_trajectory[2];
        let prev = lambda_trajectory[1];
        assert!(
            (last - prev).abs() / last < 0.15,
            "top eigenvalue not stabilizing: {lambda_trajectory:?}"
        );
    }

    #[test]
    fn merge_preserves_mass_and_compresses() {
        let ds = gaussian_mixture_2d(400, 3, 0.4, 4);
        let kernel = Kernel::gaussian(1.0);
        let mut a = StreamingShadow::new(&kernel, 4.0, 2);
        let mut b = StreamingShadow::new(&kernel, 4.0, 2);
        for i in 0..200 {
            a.observe(ds.x.row(i));
        }
        for i in 200..400 {
            b.observe(ds.x.row(i));
        }
        let m_before = a.m() + b.m();
        a.merge(&b);
        let snap = a.snapshot();
        assert_eq!(snap.n_source, 400);
        let total: f64 = snap.weights.iter().sum();
        assert!((total - 400.0).abs() < 1e-9);
        assert!(a.m() <= m_before, "merge must not inflate centers");
    }

    #[test]
    fn rejects_dimension_mismatch() {
        let kernel = Kernel::gaussian(1.0);
        let mut s = StreamingShadow::new(&kernel, 4.0, 3);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || s.observe(&[1.0, 2.0]),
        ));
        assert!(r.is_err());
    }

    /// Replay a delta onto a shadow copy of the center list (the contract
    /// `GramCache::apply_delta` uses).
    fn replay(centers: &mut Vec<Vec<f64>>, delta: &ShadowDelta) {
        for &pos in delta.removed.iter().rev() {
            centers.remove(pos);
        }
        for r in 0..delta.added.rows() {
            centers.push(delta.added.row(r).to_vec());
        }
    }

    #[test]
    fn drain_delta_reports_additions_and_bumps() {
        let ds = gaussian_mixture_2d(300, 3, 0.4, 5);
        let kernel = Kernel::gaussian(1.0);
        let mut stream = StreamingShadow::new(&kernel, 4.0, 2);
        for i in 0..150 {
            stream.observe(ds.x.row(i));
        }
        let first = stream.drain_delta();
        // First drain: everything is an addition, nothing removed.
        assert!(first.removed.is_empty());
        assert_eq!(first.added.rows(), stream.m());
        assert_eq!(first.weights.len(), stream.m());
        assert_eq!(first.n_source, 150);
        assert_eq!(first.bumped, 150 - stream.m());
        // Idle drain: empty delta.
        let idle = stream.drain_delta();
        assert!(idle.is_empty());
        // Second window: only the new centers appear.
        let m0 = stream.m();
        for i in 150..300 {
            stream.observe(ds.x.row(i));
        }
        let second = stream.drain_delta();
        assert!(second.removed.is_empty(), "no decay => no removals");
        assert_eq!(second.added.rows(), stream.m() - m0);
        assert_eq!(second.weights.len(), stream.m());
        assert_eq!(second.weights, stream.snapshot().weights);
    }

    #[test]
    fn delta_replay_reconstructs_center_ordering() {
        let ds = gaussian_mixture_2d(500, 4, 0.4, 6);
        let kernel = Kernel::gaussian(1.0);
        let mut stream =
            StreamingShadow::new(&kernel, 4.0, 2).with_decay(0.97, 0.2);
        let mut shadow_list: Vec<Vec<f64>> = Vec::new();
        for chunk in 0..5 {
            for i in (chunk * 100)..((chunk + 1) * 100) {
                stream.observe(ds.x.row(i));
            }
            let delta = stream.drain_delta();
            replay(&mut shadow_list, &delta);
            let snap = stream.snapshot();
            assert_eq!(shadow_list.len(), snap.m(), "chunk {chunk}");
            assert_eq!(delta.weights.len(), snap.m());
            for (j, row) in shadow_list.iter().enumerate() {
                assert_eq!(row.as_slice(), snap.centers.row(j));
            }
        }
    }

    #[test]
    fn decay_expires_stale_centers_and_reports_removals() {
        let kernel = Kernel::gaussian(1.0); // eps = 0.25 at ell = 4
        let mut stream =
            StreamingShadow::new(&kernel, 4.0, 2).with_decay(0.9, 0.05);
        // Cluster A, then a long run of far-away cluster B.
        for _ in 0..20 {
            stream.observe(&[0.0, 0.0]);
        }
        let first = stream.drain_delta();
        assert_eq!(first.added.rows(), 1);
        for _ in 0..200 {
            stream.observe(&[10.0, 10.0]);
        }
        let second = stream.drain_delta();
        // A's mass decayed below the floor: expired and reported.
        assert_eq!(second.removed, vec![0]);
        assert_eq!(stream.m(), 1);
        let snap = stream.snapshot();
        assert_eq!(snap.centers.row(0), &[10.0, 10.0]);
        // Renormalized weights keep the ReducedSet invariant.
        assert!(snap.check_invariants());
        assert_eq!(snap.n_source, 220);
    }

    #[test]
    fn decay_survives_long_streams_without_overflow() {
        let kernel = Kernel::gaussian(1.0);
        let mut stream =
            StreamingShadow::new(&kernel, 4.0, 1).with_decay(0.5, 1e-3);
        // 0.5^-t overflows f64 after ~1074 steps without renormalization.
        for i in 0..5000 {
            stream.observe(&[(i % 7) as f64 * 10.0]);
        }
        assert!(stream.weights.iter().all(|w| w.is_finite()));
        let snap = stream.snapshot();
        assert!(snap.check_invariants());
        assert_eq!(snap.m(), 7);
    }
}
