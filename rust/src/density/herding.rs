//! Kernel herding RSDE [Chen, Welling, Smola 2010].
//!
//! Herding greedily picks samples whose mean embedding tracks the KDE's
//! mean embedding in H: at step t, choose
//! `argmax_x  mu(x) - (1/(t+1)) Σ_{s<=t} k(x, c_s)`
//! where `mu(x) = (1/n) Σ_i k(x, x_i)` is the empirical mean map.  Chosen
//! from the dataset itself (super-samples).  Cost O(n^2 m) in the paper;
//! we cap the mean-map estimation at `mu_subsample` points so huge inputs
//! stay tractable, which preserves the selection behaviour (mu is a mean;
//! its subsampled estimate concentrates at O(1/sqrt(s))).

use super::{ReducedSet, RsdeEstimator};
use crate::kernel::Kernel;
use crate::linalg::Matrix;
use crate::prng::Pcg64;

/// Greedy kernel herding over the data points.
#[derive(Clone, Debug)]
pub struct HerdingRsde {
    pub m: usize,
    /// Cap on the number of points used to estimate the mean map mu.
    pub mu_subsample: usize,
    pub seed: u64,
}

impl HerdingRsde {
    pub fn new(m: usize, seed: u64) -> Self {
        HerdingRsde { m, mu_subsample: 2000, seed }
    }
}

impl RsdeEstimator for HerdingRsde {
    fn name(&self) -> &'static str {
        "herding"
    }

    fn reduce(&self, x: &Matrix, kernel: &Kernel) -> ReducedSet {
        let n = x.rows();
        let m = self.m.min(n).max(1);
        let mut rng = Pcg64::new(self.seed);

        // mu[i] = (1/s) sum_{j in S} k(x_i, x_j) over a subsample S.
        let s_idx = if n <= self.mu_subsample {
            (0..n).collect::<Vec<_>>()
        } else {
            rng.sample_indices(n, self.mu_subsample)
        };
        let s = s_idx.len() as f64;
        let mut mu = vec![0.0f64; n];
        for (i, mu_i) in mu.iter_mut().enumerate() {
            let row = x.row(i);
            let mut acc = 0.0;
            for &j in &s_idx {
                acc += kernel.eval(row, x.row(j));
            }
            *mu_i = acc / s;
        }

        // Greedy herding: maintain sum_sel[i] = sum_{s selected} k(x_i, c_s).
        let mut selected: Vec<usize> = Vec::with_capacity(m);
        let mut taken = vec![false; n];
        let mut sum_sel = vec![0.0f64; n];
        for t in 0..m {
            let mut best = usize::MAX;
            let mut best_score = f64::NEG_INFINITY;
            for i in 0..n {
                if taken[i] {
                    continue;
                }
                let score = mu[i] - sum_sel[i] / (t as f64 + 1.0);
                if score > best_score {
                    best_score = score;
                    best = i;
                }
            }
            selected.push(best);
            taken[best] = true;
            let brow = x.row(best);
            for i in 0..n {
                sum_sel[i] += kernel.eval(x.row(i), brow);
            }
        }

        ReducedSet {
            centers: x.select_rows(&selected),
            weights: vec![n as f64 / m as f64; m],
            n_source: n,
            assignment: None,
            method: "herding".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_mixture_2d;
    use crate::mmd::mmd_weighted;

    #[test]
    fn invariants() {
        let x = gaussian_mixture_2d(150, 3, 0.4, 1).x;
        let k = Kernel::gaussian(1.0);
        let rs = HerdingRsde::new(15, 3).reduce(&x, &k);
        assert_eq!(rs.m(), 15);
        assert!(rs.check_invariants());
        // Centers are distinct data rows.
        for i in 0..rs.m() {
            for j in (i + 1)..rs.m() {
                assert_ne!(rs.centers.row(i), rs.centers.row(j));
            }
        }
    }

    #[test]
    fn first_pick_maximizes_mean_map() {
        let x = gaussian_mixture_2d(80, 2, 0.4, 2).x;
        let k = Kernel::gaussian(1.0);
        let rs = HerdingRsde::new(1, 0).reduce(&x, &k);
        // The single herded point should have (near-)maximal KDE value.
        let kde = crate::density::Kde::new(&x, k);
        let picked = kde.eval(rs.centers.row(0));
        let max = (0..x.rows())
            .map(|i| kde.eval(x.row(i)))
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(picked >= max - 1e-9, "picked {picked} max {max}");
    }

    #[test]
    fn herding_beats_uniform_on_mmd() {
        // Herding's whole point: its super-samples track the KDE mean
        // embedding better than uniform subsampling at equal m.
        let x = gaussian_mixture_2d(300, 3, 0.5, 4).x;
        let k = Kernel::gaussian(1.0);
        let herd = HerdingRsde::new(12, 5).reduce(&x, &k);
        let mmd_h = mmd_weighted(&x, &herd.centers, &herd.weights, &k);
        // Average over several uniform draws for a fair comparison.
        let mut mmd_u_sum = 0.0;
        for seed in 0..5 {
            let uni = crate::density::UniformSubsample::new(12, seed)
                .reduce(&x, &k);
            mmd_u_sum += mmd_weighted(&x, &uni.centers, &uni.weights, &k);
        }
        let mmd_u = mmd_u_sum / 5.0;
        assert!(
            mmd_h < mmd_u,
            "herding mmd {mmd_h} not better than uniform {mmd_u}"
        );
    }
}
