//! In-tree micro/throughput bench harness (criterion is unavailable
//! offline).  The `rust/benches/*.rs` binaries (run via `cargo bench`) use
//! this to produce stable, comparable rows:
//!
//! ```text
//! bench_name                      mean 12.345ms  p50 12.1ms  p95 13.4ms  (20 iters)
//! ```
//!
//! Design choices: explicit warmup, fixed iteration counts chosen from a
//! target runtime, black-box on results, and a CSV dump hook so the
//! experiment harness can archive bench output alongside figure data.

use std::time::Instant;

use crate::metrics::Histogram;
use crate::ser::Json;

/// Structured dimensions of one benchmark row, carried into the
/// machine-readable `BENCH_*.json` artifacts so the perf trajectory can
/// be tracked across PRs instead of scraped from stdout.
#[derive(Clone, Copy, Debug, Default)]
pub struct BenchMeta {
    /// Operation family (`gram_sym`, `gemm`, `embed`, `serving`, ...).
    pub op: &'static str,
    /// Primary problem size (rows / n).
    pub n: usize,
    /// Secondary size (columns / centers); 0 when not applicable.
    pub m: usize,
    /// Feature dimension; 0 when not applicable.
    pub d: usize,
    /// Compute threads the row ran with (0 = auto).
    pub threads: usize,
}

impl BenchMeta {
    pub fn new(
        op: &'static str,
        n: usize,
        m: usize,
        d: usize,
        threads: usize,
    ) -> Self {
        BenchMeta { op, n, m, d, threads }
    }
}

/// One benchmark's collected timings.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
    /// Structured dimensions for the JSON artifact (None = untagged).
    pub meta: Option<BenchMeta>,
}

impl BenchResult {
    /// Human-readable row.
    pub fn row(&self) -> String {
        let mut s = format!(
            "{:<44} mean {:>10}  p50 {:>10}  p95 {:>10}  ({} iters)",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
            self.iters
        );
        if let Some(items) = self.items_per_iter {
            s.push_str(&format!(
                "  [{:.1} items/s]",
                items / self.mean_s
            ));
        }
        s
    }

    /// CSV row: name,iters,mean_s,p50_s,p95_s,min_s,throughput.
    pub fn csv(&self) -> String {
        format!(
            "{},{},{:.9},{:.9},{:.9},{:.9},{}",
            self.name,
            self.iters,
            self.mean_s,
            self.p50_s,
            self.p95_s,
            self.min_s,
            self.items_per_iter
                .map(|i| format!("{:.3}", i / self.mean_s))
                .unwrap_or_default()
        )
    }

    /// JSON object for the machine-readable artifact: op/n/m/d/threads
    /// from the meta tag plus ns/op and rows/s.
    pub fn json(&self) -> Json {
        let meta = self.meta.unwrap_or_default();
        let mut obj = Json::obj()
            .with("name", Json::Str(self.name.clone()))
            .with("op", Json::Str(meta.op.to_string()))
            .with("n", Json::Num(meta.n as f64))
            .with("m", Json::Num(meta.m as f64))
            .with("d", Json::Num(meta.d as f64))
            .with("threads", Json::Num(meta.threads as f64))
            .with("iters", Json::Num(self.iters as f64))
            .with("ns_per_op", Json::Num(self.mean_s * 1e9))
            .with("p50_ns", Json::Num(self.p50_s * 1e9))
            .with("p95_ns", Json::Num(self.p95_s * 1e9));
        obj = match self.items_per_iter {
            Some(items) => obj
                .with("rows_per_s", Json::Num(items / self.mean_s)),
            None => obj.with("rows_per_s", Json::Null),
        };
        obj
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}us", s * 1e6)
    }
}

/// The harness: collects results, prints rows as it goes.
#[derive(Default)]
pub struct Bencher {
    pub results: Vec<BenchResult>,
    /// Target per-benchmark measurement time (seconds).
    pub target_s: f64,
    /// Hard cap on iterations.
    pub max_iters: usize,
}

impl Bencher {
    pub fn new() -> Self {
        Bencher { results: Vec::new(), target_s: 2.0, max_iters: 200 }
    }

    /// Quick-mode harness for CI / smoke runs.
    pub fn quick() -> Self {
        Bencher { results: Vec::new(), target_s: 0.3, max_iters: 20 }
    }

    /// Benchmark a closure.  `setup` runs outside the timed region.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T)
        -> &BenchResult {
        self.bench_with_items(name, None, &mut f)
    }

    /// Benchmark with a throughput annotation (items processed per call).
    pub fn bench_throughput<T>(
        &mut self,
        name: &str,
        items_per_iter: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_items(name, Some(items_per_iter), &mut f)
    }

    /// Benchmark with a structured [`BenchMeta`] tag (op, n/m/d,
    /// threads) and a throughput annotation — the rows the JSON
    /// artifacts are built from.
    pub fn bench_meta<T>(
        &mut self,
        name: &str,
        meta: BenchMeta,
        items_per_iter: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.bench_with_items(name, Some(items_per_iter), &mut f);
        let last = self.results.last_mut().unwrap();
        last.meta = Some(meta);
        self.results.last().unwrap()
    }

    fn bench_with_items<T>(
        &mut self,
        name: &str,
        items_per_iter: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &BenchResult {
        // Warmup + calibration: time one call.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.target_s / once) as usize)
            .clamp(3, self.max_iters);

        let mut hist = Histogram::new();
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            hist.record(t.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_s: hist.mean(),
            p50_s: hist.percentile(50.0),
            p95_s: hist.percentile(95.0),
            min_s: hist.min(),
            items_per_iter,
            meta: None,
        };
        println!("{}", result.row());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Write all results as CSV (with header) to a file.
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "name,iters,mean_s,p50_s,p95_s,min_s,items_per_s")?;
        for r in &self.results {
            writeln!(f, "{}", r.csv())?;
        }
        Ok(())
    }

    /// Write all results as a machine-readable JSON array — the
    /// `BENCH_*.json` artifacts tracked at the repo root so the perf
    /// trajectory survives across PRs.
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        let rows: Vec<Json> = self.results.iter().map(|r| r.json()).collect();
        std::fs::write(path, Json::Arr(rows).to_string())
    }
}

/// Is `cargo bench` running in quick mode (RSKPCA_BENCH_QUICK set)?
pub fn quick_mode() -> bool {
    std::env::var("RSKPCA_BENCH_QUICK").is_ok()
}

/// Standard entry: quick harness under RSKPCA_BENCH_QUICK, full otherwise.
pub fn harness() -> Bencher {
    if quick_mode() {
        Bencher::quick()
    } else {
        Bencher::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher::quick();
        let r = b
            .bench("spin", || {
                let mut acc = 0u64;
                for i in 0..50_000u64 {
                    acc = acc.wrapping_add(i);
                }
                acc
            })
            .clone();
        assert!(r.iters >= 3);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s * 1.5);
        assert!(r.p50_s <= r.p95_s + 1e-12);
    }

    #[test]
    fn throughput_annotation_appears() {
        let mut b = Bencher::quick();
        let r = b.bench_throughput("t", 100.0, || 1 + 1).clone();
        assert!(r.items_per_iter == Some(100.0));
        assert!(r.row().contains("items/s"));
        assert!(r.csv().split(',').count() == 7);
    }

    #[test]
    fn csv_dump_writes_header_and_rows() {
        let mut b = Bencher::quick();
        b.bench("a", || 0);
        let path = std::env::temp_dir().join("rskpca_bench_test.csv");
        b.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("name,iters"));
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn json_dump_round_trips_meta() {
        let mut b = Bencher::quick();
        b.bench_meta(
            "gram_sym/t4/n2000",
            BenchMeta::new("gram_sym", 2000, 2000, 64, 4),
            2000.0,
            || 7,
        );
        b.bench("untagged", || 1);
        let path = std::env::temp_dir().join("rskpca_bench_test.json");
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::ser::parse(&text).unwrap();
        let rows = v.as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].req_str("op").unwrap(), "gram_sym");
        assert_eq!(rows[0].req_usize("n").unwrap(), 2000);
        assert_eq!(rows[0].req_usize("d").unwrap(), 64);
        assert_eq!(rows[0].req_usize("threads").unwrap(), 4);
        assert!(rows[0].req_f64("ns_per_op").unwrap() > 0.0);
        assert!(rows[0].req_f64("rows_per_s").unwrap() > 0.0);
        assert_eq!(rows[1].req_str("op").unwrap(), "");
        std::fs::remove_file(&path).ok();
    }
}
