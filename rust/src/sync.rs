//! Crash-only synchronization primitives: poison-recovering lock
//! accessors and the panic-isolating thread supervisor.
//!
//! **Why poison recovery is correct here.** `std` poisons a `Mutex` /
//! `RwLock` when a holder panics, and `.unwrap()` on the guard turns
//! every *subsequent* acquisition into a panic too — one crashed batch
//! poisons the `ModelRegistry` and takes the whole service down with
//! it.  All shared state in this crate is kept consistent *within* a
//! single guard scope (counters bumped, a map entry replaced, a
//! histogram sample recorded); there is no multi-step invariant that a
//! mid-panic unwind could leave half-applied.  Recovering the guard
//! with [`PoisonError::into_inner`] is therefore safe, and it converts
//! a lock-poisoning cascade into at worst one lost counter increment.
//! The repo-wide rule (enforced by a ci.sh grep gate) is: no bare
//! `.unwrap()` on a lock guard outside tests — use [`lock`], [`read`],
//! [`write`].
//!
//! **Supervision.** [`Supervisor`] wraps a thread body in
//! `catch_unwind`: a panic emits a typed `worker.panic` event, bumps
//! the `/metrics` panic/restart counters, and re-enters the body after
//! a capped exponential backoff.  A thread that keeps dying faster
//! than [`Supervisor::reset_after_ms`] trips the give-up threshold:
//! the process exits with a clear error rather than limping along with
//! a permanently broken worker (crash-only semantics — the orchestrator
//! restarts a whole process, never a half-alive one).

use std::panic::AssertUnwindSafe;
use std::sync::{
    Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard,
    RwLockWriteGuard,
};
use std::time::Instant;

use crate::obs::{Event, Obs};

/// Acquire a mutex, recovering the guard if a previous holder panicked.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Acquire a read lock, recovering the guard from poisoning.
pub fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// Acquire a write lock, recovering the guard from poisoning.
pub fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// Render a caught panic payload as a `&'static str` category for the
/// (allocation-free) event stream, with the full text to stderr.
pub fn panic_label(payload: &(dyn std::any::Any + Send)) -> &'static str {
    if payload.downcast_ref::<&str>().is_some()
        || payload.downcast_ref::<String>().is_some()
    {
        "message"
    } else {
        "opaque"
    }
}

/// What the supervisor does when a thread exceeds its restart budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GiveUp {
    /// Production: print the error and exit the process (crash-only —
    /// a permanently broken worker must not serve half a service).
    ExitProcess,
    /// Tests: return from [`Supervisor::run`] instead of exiting.
    Return,
}

/// Restart policy for one supervised thread.
#[derive(Clone, Copy, Debug)]
pub struct Supervisor {
    /// Thread label stamped on `worker.panic` events.
    pub name: &'static str,
    /// Consecutive quick failures tolerated before giving up.
    pub max_restarts: u32,
    /// First backoff sleep; doubles per consecutive failure.
    pub backoff_base_ms: u64,
    /// Backoff ceiling.
    pub backoff_cap_ms: u64,
    /// A body that ran at least this long before panicking resets the
    /// consecutive-failure count (the thread was healthy for a while).
    pub reset_after_ms: u64,
    /// Behavior past `max_restarts`.
    pub give_up: GiveUp,
}

impl Supervisor {
    /// The production policy: 50 ms · 2ⁿ backoff capped at 2 s, give up
    /// (process exit) after 8 consecutive quick deaths.
    pub fn new(name: &'static str) -> Supervisor {
        Supervisor {
            name,
            max_restarts: 8,
            backoff_base_ms: 50,
            backoff_cap_ms: 2_000,
            reset_after_ms: 10_000,
            give_up: GiveUp::ExitProcess,
        }
    }

    /// Backoff before restart number `attempt` (1-based).
    fn backoff_ms(&self, attempt: u32) -> u64 {
        let shift = attempt.saturating_sub(1).min(16);
        (self.backoff_base_ms << shift).min(self.backoff_cap_ms)
    }

    /// Run `body` until it returns normally, restarting it after each
    /// panic with capped exponential backoff.  Every panic emits a
    /// `worker.panic` event and bumps `obs.hub.worker_panics`; every
    /// restart bumps `obs.hub.worker_restarts`.  Returns the number of
    /// restarts performed (only reachable under [`GiveUp::Return`] or
    /// a normal body return).
    pub fn run<F: FnMut()>(&self, obs: &Obs, mut body: F) -> u32 {
        let mut consecutive = 0u32;
        let mut restarts = 0u32;
        loop {
            let started = Instant::now();
            match std::panic::catch_unwind(AssertUnwindSafe(&mut body)) {
                Ok(()) => return restarts,
                Err(payload) => {
                    if started.elapsed().as_millis() as u64
                        >= self.reset_after_ms
                    {
                        consecutive = 0;
                    }
                    consecutive += 1;
                    obs.hub.record_panic();
                    obs.emit(
                        Event::new("worker.panic")
                            .with("thread", self.name)
                            .with("payload", panic_label(&*payload))
                            .with("consecutive", consecutive as u64),
                    );
                    eprintln!(
                        "worker.panic: thread '{}' panicked \
                         (consecutive failure {consecutive})",
                        self.name
                    );
                    if consecutive > self.max_restarts {
                        eprintln!(
                            "supervisor: thread '{}' exceeded {} \
                             consecutive restarts; giving up",
                            self.name, self.max_restarts
                        );
                        match self.give_up {
                            GiveUp::ExitProcess => std::process::exit(17),
                            GiveUp::Return => return restarts,
                        }
                    }
                    std::thread::sleep(std::time::Duration::from_millis(
                        self.backoff_ms(consecutive),
                    ));
                    obs.hub.record_restart();
                    obs.emit(
                        Event::new("worker.restart")
                            .with("thread", self.name)
                            .with("attempt", consecutive as u64),
                    );
                    restarts += 1;
                }
            }
        }
    }
}

/// Spawn a named OS thread whose body runs under `policy`: panics are
/// caught, counted, and restarted with backoff instead of killing the
/// thread.  The returned handle joins when `body` returns normally
/// (e.g. at shutdown).
pub fn spawn_supervised<F>(
    policy: Supervisor,
    thread_name: String,
    obs: std::sync::Arc<Obs>,
    body: F,
) -> std::io::Result<std::thread::JoinHandle<()>>
where
    F: FnMut() + Send + 'static,
{
    let mut body = body;
    std::thread::Builder::new().name(thread_name).spawn(move || {
        policy.run(&obs, &mut body);
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    #[test]
    fn lock_helpers_recover_from_poisoning() {
        let m = Arc::new(Mutex::new(5usize));
        let r = Arc::new(RwLock::new(7usize));
        let (mc, rc) = (m.clone(), r.clone());
        let _ = std::thread::spawn(move || {
            let _g1 = mc.lock().unwrap();
            let _g2 = rc.write().unwrap();
            panic!("poison both");
        })
        .join();
        assert!(m.is_poisoned() && r.is_poisoned());
        // Recovering accessors still see the pre-panic values.
        assert_eq!(*lock(&m), 5);
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 6);
        assert_eq!(*read(&r), 7);
        *write(&r) = 8;
        assert_eq!(*read(&r), 8);
    }

    #[test]
    fn supervisor_restarts_until_body_succeeds() {
        let obs = Obs::default();
        let calls = AtomicU32::new(0);
        let policy = Supervisor {
            backoff_base_ms: 1,
            backoff_cap_ms: 4,
            give_up: GiveUp::Return,
            ..Supervisor::new("test-worker")
        };
        let restarts = policy.run(&obs, || {
            if calls.fetch_add(1, Ordering::SeqCst) < 3 {
                panic!("flaky");
            }
        });
        assert_eq!(restarts, 3);
        assert_eq!(calls.load(Ordering::SeqCst), 4);
        assert_eq!(obs.events_named("worker.panic").len(), 3);
        assert_eq!(obs.events_named("worker.restart").len(), 3);
        assert_eq!(obs.hub.worker_panics(), 3);
        assert_eq!(obs.hub.worker_restarts(), 3);
    }

    #[test]
    fn supervisor_gives_up_after_max_restarts() {
        let obs = Obs::default();
        let calls = AtomicU32::new(0);
        let policy = Supervisor {
            max_restarts: 2,
            backoff_base_ms: 1,
            backoff_cap_ms: 2,
            give_up: GiveUp::Return,
            ..Supervisor::new("doomed")
        };
        policy.run(&obs, || {
            calls.fetch_add(1, Ordering::SeqCst);
            panic!("always");
        });
        // Initial run + max_restarts retries, then give up.
        assert_eq!(calls.load(Ordering::SeqCst), 3);
        assert_eq!(obs.events_named("worker.panic").len(), 3);
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let p = Supervisor {
            backoff_base_ms: 50,
            backoff_cap_ms: 2_000,
            ..Supervisor::new("x")
        };
        assert_eq!(p.backoff_ms(1), 50);
        assert_eq!(p.backoff_ms(2), 100);
        assert_eq!(p.backoff_ms(3), 200);
        assert_eq!(p.backoff_ms(7), 2_000);
        assert_eq!(p.backoff_ms(60), 2_000); // shift clamp, no overflow
    }

    #[test]
    fn spawn_supervised_joins_on_normal_return() {
        let obs = Arc::new(Obs::default());
        let policy = Supervisor {
            backoff_base_ms: 1,
            backoff_cap_ms: 2,
            give_up: GiveUp::Return,
            ..Supervisor::new("spawned")
        };
        let n = Arc::new(AtomicU32::new(0));
        let nc = n.clone();
        let h = spawn_supervised(
            policy,
            "rskpca-test-supervised".into(),
            obs.clone(),
            move || {
                if nc.fetch_add(1, Ordering::SeqCst) == 0 {
                    panic!("first run dies");
                }
            },
        )
        .unwrap();
        h.join().unwrap();
        assert_eq!(n.load(Ordering::SeqCst), 2);
        assert_eq!(obs.hub.worker_panics(), 1);
    }
}
