//! Hand-rolled HTTP/1.1 substrate (std-only; no hyper, no tokio).
//!
//! Covers exactly what the serving layer needs: a buffered,
//! split-read-tolerant request parser ([`RequestReader`]) that preserves
//! pipelined leftovers across keep-alive requests and exposes both a
//! push (`push_bytes`/`try_next`, for the event loop) and a pull
//! (`next_request`, blocking) interface over the same state machine, a
//! response writer ([`Response`]), the client-side mirror
//! ([`ResponseReader`]) for the multiplexed load generator, and a tiny
//! blocking keep-alive client ([`ClientConn`]) shared by the CI smoke
//! step and the integration tests.
//!
//! Scope limits are deliberate: no chunked transfer encoding (501), no
//! TLS, no multipart — request bodies are length-delimited JSON.  Every
//! protocol violation maps to a 4xx/5xx status via [`HttpError::Bad`]
//! so a malformed client can never wedge a connection worker.

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::error::Error;
use crate::ser::Json;

/// Hard cap on the request/response head (request line + headers).
const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Read-buffer granularity.
const READ_CHUNK: usize = 4096;

/// How an HTTP read can fail.
#[derive(Debug)]
pub enum HttpError {
    /// The peer closed the connection cleanly at a message boundary
    /// (EOF before the first byte of a new message) — not an error,
    /// just the end of a keep-alive session.
    Closed,
    /// Protocol violation; `status` is what to send before closing.
    Bad { status: u16, msg: String },
    /// Transport failure (including read timeouts on idle connections).
    Io(std::io::Error),
}

impl HttpError {
    fn bad(status: u16, msg: &str) -> HttpError {
        HttpError::Bad { status, msg: msg.to_string() }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Closed => write!(f, "connection closed"),
            HttpError::Bad { status, msg } => {
                write!(f, "http {status}: {msg}")
            }
            HttpError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl From<HttpError> for Error {
    fn from(e: HttpError) -> Error {
        Error::Service(format!("http: {e}"))
    }
}

/// A parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Method token, verbatim (e.g. "GET", "POST").
    pub method: String,
    /// Request target, verbatim (path plus optional query string).
    pub target: String,
    /// Protocol version (e.g. "HTTP/1.1").
    pub version: String,
    /// Headers in arrival order; names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
    /// Length-delimited body (empty when no `content-length`).
    pub body: Vec<u8>,
}

impl Request {
    /// First header value under `name` (lowercase).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// The path component of the target (query string stripped).
    pub fn path(&self) -> &str {
        match self.target.split_once('?') {
            Some((path, _)) => path,
            None => &self.target,
        }
    }

    /// HTTP/1.1 defaults to keep-alive, HTTP/1.0 to close; an explicit
    /// `Connection` header overrides either way.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(|v| v.to_ascii_lowercase()) {
            Some(v) if v == "close" => false,
            Some(v) if v == "keep-alive" => true,
            _ => self.version == "HTTP/1.1",
        }
    }
}

/// Stateful per-connection request reader.  Tolerates arbitrarily split
/// reads (a request head or body may arrive one byte at a time) and
/// preserves bytes read past the current message for the next call, so
/// pipelined keep-alive requests are never dropped.
///
/// Two consumption styles share one parser:
///
/// * **push** ([`RequestReader::push_bytes`] + [`RequestReader::try_next`])
///   — the event loop feeds whatever the socket had and asks for
///   complete requests; `Ok(None)` means "need more bytes".
/// * **pull** ([`RequestReader::next_request`]) — the blocking form
///   used by tests and any synchronous caller: read, push, retry.
#[derive(Debug, Default)]
pub struct RequestReader {
    buf: Vec<u8>,
}

impl RequestReader {
    pub fn new() -> RequestReader {
        RequestReader::default()
    }

    /// Bytes buffered but not yet consumed (a partial message and/or
    /// pipelined followers).  The event loop uses this both for its
    /// memory accounting and to decide whether an idle connection is
    /// mid-request (slow-loris) or between requests.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Append bytes received from the transport.
    pub fn push_bytes(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Try to parse one complete request out of the buffered bytes.
    /// `Ok(None)` means the message is still incomplete; protocol
    /// violations fail eagerly — an oversized head or declared-oversized
    /// body errors as soon as it is evident, without waiting for the
    /// rest of the message to arrive.
    pub fn try_next(
        &mut self,
        max_body: usize,
    ) -> Result<Option<Request>, HttpError> {
        let Some(header_end) = find_head_end(&self.buf) else {
            // No terminator yet: once the buffer is past the limit the
            // eventual terminator position can only be worse.
            if self.buf.len() > MAX_HEAD_BYTES + 3 {
                return Err(HttpError::bad(
                    431,
                    "message head exceeds 16 KiB",
                ));
            }
            return Ok(None);
        };
        // The limit applies to the head itself, not to how much
        // happened to arrive in one read (pipelined bytes after the
        // terminator are legitimate).
        if header_end > MAX_HEAD_BYTES {
            return Err(HttpError::bad(
                431,
                "message head exceeds 16 KiB",
            ));
        }
        // Own the head so the buffer can be drained afterwards.
        let head = match std::str::from_utf8(&self.buf[..header_end]) {
            Ok(s) => s.to_string(),
            Err(_) => {
                return Err(HttpError::bad(400, "non-utf8 request head"))
            }
        };
        let mut lines = head.split("\r\n");
        let request_line = lines
            .next()
            .ok_or_else(|| HttpError::bad(400, "empty request head"))?;
        let mut parts = request_line.split(' ');
        let (Some(method), Some(target), Some(version), None) =
            (parts.next(), parts.next(), parts.next(), parts.next())
        else {
            return Err(HttpError::bad(400, "malformed request line"));
        };
        if method.is_empty() || target.is_empty() {
            return Err(HttpError::bad(400, "malformed request line"));
        }
        if !version.starts_with("HTTP/1.") {
            return Err(HttpError::bad(
                505,
                "only HTTP/1.x is supported",
            ));
        }
        let headers = parse_headers(lines)?;
        if headers
            .iter()
            .any(|(k, _)| k == "transfer-encoding")
        {
            return Err(HttpError::bad(
                501,
                "transfer-encoding is not supported; send \
                 content-length",
            ));
        }
        let content_length = content_length(&headers)?;
        if content_length > max_body {
            return Err(HttpError::bad(
                413,
                &format!(
                    "body of {content_length} bytes exceeds the \
                     {max_body}-byte limit"
                ),
            ));
        }
        let body_start = header_end + 4;
        let total = body_start + content_length;
        if self.buf.len() < total {
            return Ok(None);
        }
        let body = self.buf[body_start..total].to_vec();
        self.buf.drain(..total);
        Ok(Some(Request {
            method: method.to_string(),
            target: target.to_string(),
            version: version.to_string(),
            headers,
            body,
        }))
    }

    /// Read one full request from `stream` (blocking form).
    pub fn next_request(
        &mut self,
        stream: &mut impl Read,
        max_body: usize,
    ) -> Result<Request, HttpError> {
        loop {
            if let Some(req) = self.try_next(max_body)? {
                return Ok(req);
            }
            let mut tmp = [0u8; READ_CHUNK];
            let n = stream.read(&mut tmp).map_err(HttpError::Io)?;
            if n == 0 {
                return if self.buf.is_empty() {
                    Err(HttpError::Closed)
                } else {
                    Err(HttpError::bad(400, "truncated message"))
                };
            }
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }
}

/// Offset of the `\r\n\r\n` head terminator, if buffered.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Parse `name: value` lines; names are lowercased, values trimmed.
fn parse_headers<'a>(
    lines: impl Iterator<Item = &'a str>,
) -> Result<Vec<(String, String)>, HttpError> {
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) = line.split_once(':').ok_or_else(|| {
            HttpError::bad(400, "header line without ':'")
        })?;
        if name.is_empty() || name.contains(' ') {
            return Err(HttpError::bad(400, "malformed header name"));
        }
        headers.push((
            name.to_ascii_lowercase(),
            value.trim().to_string(),
        ));
    }
    Ok(headers)
}

/// Extract and validate `content-length` (0 when absent).  Duplicate
/// headers with disagreeing values are a request-smuggling vector and
/// are rejected outright.
fn content_length(
    headers: &[(String, String)],
) -> Result<usize, HttpError> {
    let mut length: Option<usize> = None;
    for (k, v) in headers {
        if k != "content-length" {
            continue;
        }
        let parsed = v.parse::<usize>().map_err(|_| {
            HttpError::bad(400, &format!("bad content-length '{v}'"))
        })?;
        match length {
            Some(prev) if prev != parsed => {
                return Err(HttpError::bad(
                    400,
                    "conflicting content-length headers",
                ));
            }
            _ => length = Some(parsed),
        }
    }
    Ok(length.unwrap_or(0))
}

/// Canonical reason phrase for the status codes the server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Unknown",
    }
}

/// An outgoing response.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra headers (e.g. `Retry-After`) appended verbatim.
    pub extra_headers: Vec<(String, String)>,
}

impl Response {
    /// A JSON-bodied response.
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.to_string().into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    /// An error response with a `{"error": ..., "status": ...}` body.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(
            status,
            &Json::obj()
                .with("error", Json::Str(msg.to_string()))
                .with("status", Json::Num(status as f64)),
        )
    }

    /// Builder-style extra header.
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.extra_headers
            .push((name.to_string(), value.to_string()));
        self
    }

    /// Serialize to a byte vector.  `keep_alive` selects the
    /// `Connection` header; the body is always length-delimited.  The
    /// event loop queues these bytes on the connection's write buffer
    /// and drains them as the socket becomes writable.
    pub fn to_bytes(&self, keep_alive: bool) -> Vec<u8> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nserver: rskpca\r\ncontent-type: {}\r\n\
             content-length: {}\r\nconnection: {}\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        );
        for (k, v) in &self.extra_headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        let mut wire = head.into_bytes();
        wire.extend_from_slice(&self.body);
        wire
    }

    /// Serialize onto a blocking writer.
    pub fn write_to(
        &self,
        w: &mut impl Write,
        keep_alive: bool,
    ) -> std::io::Result<()> {
        w.write_all(&self.to_bytes(keep_alive))?;
        w.flush()
    }
}

/// A parsed response on the client side.
#[derive(Clone, Debug)]
pub struct ClientResponse {
    pub status: u16,
    /// Headers in arrival order; names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    /// First header value under `name` (lowercase).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> crate::error::Result<Json> {
        let text = std::str::from_utf8(&self.body).map_err(|_| {
            Error::Parse("non-utf8 response body".into())
        })?;
        crate::ser::parse(text)
    }
}

/// Stateful incremental response parser — the client-side mirror of
/// [`RequestReader`], used by the multiplexed load generator's
/// per-connection state machines (and, in pull form, by
/// [`ClientConn`]).
#[derive(Debug, Default)]
pub struct ResponseReader {
    buf: Vec<u8>,
}

impl ResponseReader {
    pub fn new() -> ResponseReader {
        ResponseReader::default()
    }

    /// Bytes buffered but not yet consumed.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Append bytes received from the transport.
    pub fn push_bytes(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Try to parse one complete response out of the buffered bytes;
    /// `Ok(None)` means the message is still incomplete.
    pub fn try_next(
        &mut self,
    ) -> Result<Option<ClientResponse>, HttpError> {
        let Some(header_end) = find_head_end(&self.buf) else {
            if self.buf.len() > MAX_HEAD_BYTES + 3 {
                return Err(HttpError::bad(
                    431,
                    "message head exceeds 16 KiB",
                ));
            }
            return Ok(None);
        };
        let head = match std::str::from_utf8(&self.buf[..header_end]) {
            Ok(s) => s.to_string(),
            Err(_) => {
                return Err(HttpError::bad(400, "non-utf8 response head"))
            }
        };
        let mut lines = head.split("\r\n");
        let status_line = lines
            .next()
            .ok_or_else(|| HttpError::bad(400, "empty response head"))?;
        let mut parts = status_line.splitn(3, ' ');
        let version = parts
            .next()
            .ok_or_else(|| HttpError::bad(400, "bad status line"))?;
        if !version.starts_with("HTTP/1.") {
            return Err(HttpError::bad(400, "bad status line"));
        }
        let status = parts
            .next()
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| HttpError::bad(400, "bad status code"))?;
        let headers = parse_headers(lines)?;
        let content_length = content_length(&headers)?;
        let body_start = header_end + 4;
        let total = body_start + content_length;
        if self.buf.len() < total {
            return Ok(None);
        }
        let body = self.buf[body_start..total].to_vec();
        self.buf.drain(..total);
        Ok(Some(ClientResponse { status, headers, body }))
    }
}

/// Read one full response (status line, headers, length-delimited
/// body) from `stream`, buffering through `reader` across calls
/// (blocking form).
pub(crate) fn read_client_response(
    stream: &mut impl Read,
    reader: &mut ResponseReader,
) -> Result<ClientResponse, HttpError> {
    loop {
        if let Some(resp) = reader.try_next()? {
            return Ok(resp);
        }
        let mut tmp = [0u8; READ_CHUNK];
        let n = stream.read(&mut tmp).map_err(HttpError::Io)?;
        if n == 0 {
            return if reader.buf.is_empty() {
                Err(HttpError::Closed)
            } else {
                Err(HttpError::bad(400, "truncated response"))
            };
        }
        reader.buf.extend_from_slice(&tmp[..n]);
    }
}

/// A blocking keep-alive HTTP/1.1 client connection.
#[derive(Debug)]
pub struct ClientConn {
    stream: TcpStream,
    reader: ResponseReader,
}

impl ClientConn {
    /// Connect to `addr` ("host:port") with the given timeout; the
    /// connection uses TCP_NODELAY and a 30 s read timeout.
    pub fn connect(
        addr: &str,
        timeout: Duration,
    ) -> crate::error::Result<ClientConn> {
        let sock = addr
            .to_socket_addrs()
            .map_err(|e| Error::Io(format!("resolve {addr}: {e}")))?
            .next()
            .ok_or_else(|| {
                Error::Io(format!("{addr}: no usable address"))
            })?;
        let stream =
            TcpStream::connect_timeout(&sock, timeout).map_err(|e| {
                Error::Io(format!("connect {addr}: {e}"))
            })?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
        Ok(ClientConn { stream, reader: ResponseReader::new() })
    }

    /// One request/response round trip (closed-loop).  `body` may be
    /// empty for GETs.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> crate::error::Result<ClientResponse> {
        self.request_with_headers(method, path, &[], body)
    }

    /// [`ClientConn::request`] with extra request headers (for
    /// per-request metadata such as `X-Deadline-Ms`).
    pub fn request_with_headers(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> crate::error::Result<ClientResponse> {
        let mut extra = String::new();
        for (k, v) in headers {
            extra.push_str(k);
            extra.push_str(": ");
            extra.push_str(v);
            extra.push_str("\r\n");
        }
        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: rskpca\r\n\
             content-type: application/json\r\n\
             {extra}content-length: {}\r\n\r\n",
            body.len()
        );
        self.stream
            .write_all(head.as_bytes())
            .and_then(|()| self.stream.write_all(body))
            .and_then(|()| self.stream.flush())
            .map_err(|e| Error::Io(format!("send {method} {path}: {e}")))?;
        read_client_response(&mut self.stream, &mut self.reader)
            .map_err(Error::from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A reader that trickles its data `chunk` bytes per `read` call —
    /// the pathological split-read source.
    struct Trickle<'a> {
        data: &'a [u8],
        at: usize,
        chunk: usize,
    }

    impl Read for Trickle<'_> {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            let n = self
                .chunk
                .min(out.len())
                .min(self.data.len() - self.at);
            out[..n].copy_from_slice(&self.data[self.at..self.at + n]);
            self.at += n;
            Ok(n)
        }
    }

    fn parse_one(
        raw: &[u8],
        chunk: usize,
        max_body: usize,
    ) -> Result<Request, HttpError> {
        let mut src = Trickle { data: raw, at: 0, chunk };
        RequestReader::new().next_request(&mut src, max_body)
    }

    #[test]
    fn parses_request_under_split_reads() {
        let raw = b"POST /embed?x=1 HTTP/1.1\r\nHost: h\r\n\
                    Content-Length: 11\r\n\r\nhello world";
        for chunk in [1, 2, 3, 7, 4096] {
            let req = parse_one(raw, chunk, 1024).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.target, "/embed?x=1");
            assert_eq!(req.path(), "/embed");
            assert_eq!(req.version, "HTTP/1.1");
            assert_eq!(req.header("host"), Some("h"));
            assert_eq!(req.body, b"hello world");
            assert!(req.keep_alive());
        }
    }

    #[test]
    fn push_interface_parses_incrementally() {
        let raw = b"POST /embed HTTP/1.1\r\ncontent-length: 5\r\n\r\nhello";
        let mut reader = RequestReader::new();
        // Feed one byte at a time; try_next must report "incomplete"
        // at every prefix and produce the request exactly once, at the
        // final byte.
        for (i, b) in raw.iter().enumerate() {
            reader.push_bytes(&[*b]);
            let got = reader.try_next(1024).unwrap();
            if i + 1 < raw.len() {
                assert!(got.is_none(), "complete at byte {i}?");
            } else {
                let req = got.expect("request at final byte");
                assert_eq!(req.method, "POST");
                assert_eq!(req.body, b"hello");
            }
        }
        assert_eq!(reader.buffered(), 0);
        // Idempotent on an empty buffer.
        assert!(reader.try_next(1024).unwrap().is_none());
    }

    #[test]
    fn push_interface_fails_eagerly_on_declared_oversize() {
        // 413 must fire as soon as the head is parsed — before any
        // body bytes arrive — so a client can't hold buffer space with
        // a huge declared length.
        let head = b"POST / HTTP/1.1\r\ncontent-length: 999\r\n\r\n";
        let mut reader = RequestReader::new();
        reader.push_bytes(head);
        match reader.try_next(100) {
            Err(HttpError::Bad { status: 413, .. }) => {}
            other => panic!("expected 413, got {other:?}"),
        }
    }

    #[test]
    fn response_reader_parses_incrementally() {
        let resp = Response::json(
            200,
            &Json::obj().with("ok", Json::Bool(true)),
        );
        let wire = resp.to_bytes(true);
        let mut reader = ResponseReader::new();
        for (i, b) in wire.iter().enumerate() {
            reader.push_bytes(&[*b]);
            let got = reader.try_next().unwrap();
            if i + 1 < wire.len() {
                assert!(got.is_none(), "complete at byte {i}?");
            } else {
                let parsed = got.expect("response at final byte");
                assert_eq!(parsed.status, 200);
                assert_eq!(
                    parsed.header("connection"),
                    Some("keep-alive")
                );
            }
        }
        assert_eq!(reader.buffered(), 0);
    }

    #[test]
    fn pipelined_requests_survive_the_buffer() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\n\
                    POST /embed HTTP/1.1\r\ncontent-length: 2\r\n\r\nok";
        let mut src = Trickle { data: raw, at: 0, chunk: 5 };
        let mut reader = RequestReader::new();
        let first = reader.next_request(&mut src, 1024).unwrap();
        assert_eq!(first.method, "GET");
        assert_eq!(first.path(), "/healthz");
        assert!(first.body.is_empty());
        let second = reader.next_request(&mut src, 1024).unwrap();
        assert_eq!(second.method, "POST");
        assert_eq!(second.body, b"ok");
        // Clean close at the boundary.
        assert!(matches!(
            reader.next_request(&mut src, 1024),
            Err(HttpError::Closed)
        ));
    }

    #[test]
    fn oversized_body_is_413() {
        let raw = b"POST /embed HTTP/1.1\r\ncontent-length: 999\r\n\r\n";
        match parse_one(raw, 4096, 100) {
            Err(HttpError::Bad { status: 413, .. }) => {}
            other => panic!("expected 413, got {other:?}"),
        }
    }

    #[test]
    fn bad_content_length_is_400() {
        for raw in [
            &b"POST / HTTP/1.1\r\ncontent-length: abc\r\n\r\n"[..],
            &b"POST / HTTP/1.1\r\ncontent-length: -5\r\n\r\n"[..],
            &b"POST / HTTP/1.1\r\ncontent-length: 2\r\n\
               content-length: 7\r\n\r\nhi"[..],
        ] {
            match parse_one(raw, 4096, 1024) {
                Err(HttpError::Bad { status: 400, .. }) => {}
                other => panic!("expected 400, got {other:?}"),
            }
        }
        // Agreeing duplicates are tolerated.
        let raw = b"POST / HTTP/1.1\r\ncontent-length: 2\r\n\
                    content-length: 2\r\n\r\nhi";
        assert_eq!(parse_one(raw, 4096, 1024).unwrap().body, b"hi");
    }

    #[test]
    fn truncated_messages_are_400_not_hangs() {
        // EOF mid-head.
        match parse_one(b"GET / HT", 3, 1024) {
            Err(HttpError::Bad { status: 400, .. }) => {}
            other => panic!("expected 400, got {other:?}"),
        }
        // EOF mid-body.
        let raw = b"POST / HTTP/1.1\r\ncontent-length: 50\r\n\r\nshort";
        match parse_one(raw, 4096, 1024) {
            Err(HttpError::Bad { status: 400, .. }) => {}
            other => panic!("expected 400, got {other:?}"),
        }
        // EOF before any byte is a clean close.
        assert!(matches!(
            parse_one(b"", 1, 1024),
            Err(HttpError::Closed)
        ));
    }

    #[test]
    fn protocol_violations_map_to_statuses() {
        // Head too large -> 431.
        let mut huge = b"GET / HTTP/1.1\r\n".to_vec();
        huge.extend_from_slice(
            format!("x-pad: {}\r\n\r\n", "a".repeat(20_000)).as_bytes(),
        );
        match parse_one(&huge, 4096, 1024) {
            Err(HttpError::Bad { status: 431, .. }) => {}
            other => panic!("expected 431, got {other:?}"),
        }
        // Chunked -> 501.
        let raw = b"POST / HTTP/1.1\r\n\
                    transfer-encoding: chunked\r\n\r\n";
        match parse_one(raw, 4096, 1024) {
            Err(HttpError::Bad { status: 501, .. }) => {}
            other => panic!("expected 501, got {other:?}"),
        }
        // Unknown protocol -> 505.
        match parse_one(b"GET / SPDY/3\r\n\r\n", 4096, 1024) {
            Err(HttpError::Bad { status: 505, .. }) => {}
            other => panic!("expected 505, got {other:?}"),
        }
        // Garbage request line -> 400.
        match parse_one(b"ONE-TOKEN\r\n\r\n", 4096, 1024) {
            Err(HttpError::Bad { status: 400, .. }) => {}
            other => panic!("expected 400, got {other:?}"),
        }
    }

    #[test]
    fn keep_alive_defaults_follow_the_version() {
        let req = parse_one(b"GET / HTTP/1.0\r\n\r\n", 4096, 0).unwrap();
        assert!(!req.keep_alive());
        let raw = b"GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n";
        assert!(parse_one(raw, 4096, 0).unwrap().keep_alive());
        let raw = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        assert!(!parse_one(raw, 4096, 0).unwrap().keep_alive());
    }

    #[test]
    fn response_roundtrips_through_client_parser() {
        let resp = Response::json(
            200,
            &Json::obj().with("ok", Json::Bool(true)),
        )
        .with_header("retry-after", "1");
        let mut wire = Vec::new();
        resp.write_to(&mut wire, true).unwrap();
        let mut src = &wire[..];
        let mut reader = ResponseReader::new();
        let parsed =
            read_client_response(&mut src, &mut reader).unwrap();
        assert_eq!(parsed.status, 200);
        assert_eq!(parsed.header("retry-after"), Some("1"));
        assert_eq!(parsed.header("connection"), Some("keep-alive"));
        let v = parsed.json().unwrap();
        assert_eq!(v.get("ok").and_then(|b| b.as_bool()), Some(true));

        let err = Response::error(429, "slow down");
        let mut wire = Vec::new();
        err.write_to(&mut wire, false).unwrap();
        let mut src = &wire[..];
        let parsed =
            read_client_response(&mut src, &mut ResponseReader::new())
                .unwrap();
        assert_eq!(parsed.status, 429);
        assert_eq!(parsed.header("connection"), Some("close"));
        assert_eq!(
            parsed.json().unwrap().req_str("error").unwrap(),
            "slow down"
        );
    }
}
