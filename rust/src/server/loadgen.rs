//! Load generator over multiplexed non-blocking connections: a few
//! shard threads each drive up to [`CONNS_PER_SHARD`] keep-alive
//! connections through the same `poll(2)` shim the server uses, so
//! `--concurrency 1000` costs ~4 threads, not 1000.
//!
//! Two offered-load models:
//!
//! * **closed-loop** (default): every connection replays `POST /embed`
//!   back-to-back — a new request is issued only after the previous
//!   reply lands, so offered load adapts to service capacity.
//! * **open-loop** (`rate > 0`): requests fire on a fixed global
//!   schedule regardless of completions; a tick with no idle
//!   connection is counted as an *overrun* instead of silently
//!   queueing, which is what makes saturation visible.
//!
//! Aggregates per-shard latency histograms into a throughput /
//! percentile report (machine-readable via
//! [`LoadgenReport::to_json`]); 429s are counted separately from hard
//! errors, making admission control directly observable.
//!
//! Used by the `rskpca loadgen` CLI subcommand, the CI smoke step, the
//! loopback integration tests, and `benches/bench_serving.rs`.

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::event::{poll_fds, stream_fd, PollFd, POLLIN, POLLOUT};
use super::http::{ClientConn, ClientResponse, ResponseReader};
use crate::error::{Error, Result};
use crate::metrics::Histogram;
use crate::obs::prom;
use crate::prng::Pcg64;
use crate::ser::Json;

/// Connect timeout for each client connection.
const CONNECT_TIMEOUT: Duration = Duration::from_millis(2000);

/// Connections per shard thread; `--concurrency 1000` → 4 shards.
const CONNS_PER_SHARD: usize = 256;

/// Upper bound on shard threads.
const MAX_SHARDS: usize = 8;

/// Load-generator knobs.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address: "host:port" (an `http://` prefix is tolerated).
    pub target: String,
    /// Concurrent keep-alive connections (multiplexed, not threads).
    pub clients: usize,
    /// Requests each connection issues.
    pub requests_per_client: usize,
    /// Rows per `POST /embed` request.
    pub rows_per_request: usize,
    /// Feature dimension of generated rows; 0 = discover from
    /// `GET /models`.
    pub dim: usize,
    /// PRNG seed (each connection derives its own stream).
    pub seed: u64,
    /// How long to poll `GET /healthz` before giving up.
    pub warmup_ms: u64,
    /// Open-loop offered rate in requests/s across all connections;
    /// 0 = closed loop.
    pub rate: f64,
    /// Scrape `GET /metrics` every N seconds while the run is in
    /// flight (strictly parsed; samples land in the report);
    /// 0 = no polling.
    pub metrics_poll_s: u64,
    /// Re-send 429/503 responses after the server's `Retry-After`
    /// hint (plus jitter) instead of counting them rejected.  Each
    /// request retries at most [`MAX_RETRIES`] times before being
    /// tallied as rejected after all.
    pub retry: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            target: "127.0.0.1:7878".into(),
            clients: 4,
            requests_per_client: 50,
            rows_per_request: 8,
            dim: 0,
            seed: 0x10AD,
            warmup_ms: 5000,
            rate: 0.0,
            metrics_poll_s: 0,
            retry: false,
        }
    }
}

/// Retry budget per request under `--retry`, so a permanently
/// saturated server cannot keep the run alive forever.
const MAX_RETRIES: u32 = 8;

/// Fallback backoff when a 429/503 carries no usable hint.
const RETRY_FALLBACK_MS: u64 = 100;

/// One mid-run `GET /metrics` scrape captured by `--metrics-poll`.
/// Each scrape is validated by the strict [`prom::parse`] checker, so
/// a malformed exposition fails the run's report instead of passing
/// silently.
#[derive(Clone, Debug, Default)]
pub struct MetricsSample {
    /// Seconds since the load run started.
    pub t_s: f64,
    /// `rskpca_requests_total` at scrape time.
    pub requests_total: f64,
    /// `rskpca_http_conns_open` at scrape time.
    pub conns_open: f64,
    /// `rskpca_requests_1m` at scrape time.
    pub requests_1m: f64,
    /// Parsed sample lines in the document (exposition-size signal).
    pub series: usize,
}

impl MetricsSample {
    fn to_json(&self) -> Json {
        Json::obj()
            .with("t_s", Json::Num(self.t_s))
            .with("requests_total", Json::Num(self.requests_total))
            .with("conns_open", Json::Num(self.conns_open))
            .with("requests_1m", Json::Num(self.requests_1m))
            .with("series", Json::Num(self.series as f64))
    }
}

/// Aggregated results of one load-generation run.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    pub clients: usize,
    pub requests_ok: u64,
    /// 429 responses (admission control working as designed).
    pub rejected: u64,
    /// 504 responses: the request's end-to-end deadline expired and
    /// the server shed the work before compute.  Counted apart from
    /// `errors` — a 504 is the deadline machinery working as designed.
    pub deadline_504: u64,
    /// Re-sends performed under `--retry` (each counted once per
    /// re-issued attempt; the final outcome lands in ok/rejected).
    pub retries: u64,
    /// Transport failures and non-200/429 statuses.
    pub errors: u64,
    /// Open-loop ticks that found no idle connection (offered load
    /// exceeded what the concurrency level could carry).
    pub overruns: u64,
    pub rows_ok: u64,
    pub wall_s: f64,
    /// End-to-end request latency of successful requests, microseconds.
    pub latency_us: Histogram,
    /// Mid-run `GET /metrics` scrapes (empty unless `--metrics-poll`).
    pub metrics_samples: Vec<MetricsSample>,
    /// Scrapes that failed (connect error, non-200, or a document the
    /// strict parser rejected).
    pub metrics_errors: u64,
}

impl LoadgenReport {
    /// Successful rows per second of wall time.
    pub fn rows_per_s(&self) -> f64 {
        self.rows_ok as f64 / self.wall_s.max(1e-9)
    }

    /// Successful requests per second of wall time.
    pub fn requests_per_s(&self) -> f64 {
        self.requests_ok as f64 / self.wall_s.max(1e-9)
    }

    /// Median latency of successful requests, microseconds.
    pub fn p50_us(&mut self) -> f64 {
        self.latency_us.percentile(50.0)
    }

    /// Tail latency of successful requests, microseconds.
    pub fn p99_us(&mut self) -> f64 {
        self.latency_us.p99()
    }

    /// Machine-readable summary (written by `rskpca loadgen --json`).
    pub fn to_json(&mut self) -> Json {
        Json::obj()
            .with("clients", Json::Num(self.clients as f64))
            .with("requests_ok", Json::Num(self.requests_ok as f64))
            .with("rejected", Json::Num(self.rejected as f64))
            .with("deadline_504", Json::Num(self.deadline_504 as f64))
            .with("retries", Json::Num(self.retries as f64))
            .with("errors", Json::Num(self.errors as f64))
            .with("overruns", Json::Num(self.overruns as f64))
            .with("rows_ok", Json::Num(self.rows_ok as f64))
            .with("wall_s", Json::Num(self.wall_s))
            .with("rows_per_s", Json::Num(self.rows_per_s()))
            .with("requests_per_s", Json::Num(self.requests_per_s()))
            .with("latency_mean_us", Json::Num(self.latency_us.mean()))
            .with("latency_p50_us", Json::Num(self.p50_us()))
            .with(
                "latency_p95_us",
                Json::Num(self.latency_us.percentile(95.0)),
            )
            .with("latency_p99_us", Json::Num(self.p99_us()))
            .with(
                "metrics_samples",
                Json::Arr(
                    self.metrics_samples
                        .iter()
                        .map(MetricsSample::to_json)
                        .collect(),
                ),
            )
            .with(
                "metrics_errors",
                Json::Num(self.metrics_errors as f64),
            )
    }

    /// Multi-line human-readable report.
    pub fn render(&mut self) -> String {
        let total = self.requests_ok
            + self.rejected
            + self.deadline_504
            + self.errors;
        let max_us = if self.latency_us.is_empty() {
            0.0
        } else {
            self.latency_us.max()
        };
        let mut extras = String::new();
        if self.deadline_504 > 0 {
            extras += &format!(", {} deadline (504)", self.deadline_504);
        }
        if self.retries > 0 {
            extras += &format!(", {} retries", self.retries);
        }
        if self.overruns > 0 {
            extras += &format!(", {} overruns", self.overruns);
        }
        format!(
            "loadgen: {total} requests from {} clients in {:.3}s — \
             {} ok, {} rejected (429), {} errors{extras}\n\
             throughput: {:.0} rows/s ({:.1} req/s)\n\
             latency: mean={:.0}us p50={:.0}us p95={:.0}us \
             p99={:.0}us max={:.0}us",
            self.clients,
            self.wall_s,
            self.requests_ok,
            self.rejected,
            self.errors,
            self.rows_per_s(),
            self.requests_per_s(),
            self.latency_us.mean(),
            self.latency_us.percentile(50.0),
            self.latency_us.percentile(95.0),
            self.latency_us.p99(),
            max_us,
        )
    }
}

/// Accept "host:port", "http://host:port" or a trailing slash.
pub fn normalize_target(target: &str) -> String {
    let t = target.strip_prefix("http://").unwrap_or(target);
    t.trim_end_matches('/').to_string()
}

/// Poll `GET /healthz` until it answers 200 or `budget` expires.
pub fn wait_healthy(target: &str, budget: Duration) -> Result<()> {
    let deadline = Instant::now() + budget;
    loop {
        if let Ok(mut conn) =
            ClientConn::connect(target, Duration::from_millis(250))
        {
            if let Ok(resp) = conn.request("GET", "/healthz", b"") {
                if resp.status == 200 {
                    return Ok(());
                }
            }
        }
        if Instant::now() >= deadline {
            return Err(Error::Service(format!(
                "server at {target} not healthy within {budget:?}"
            )));
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// Discover the serving model's feature dimension via `GET /models`.
pub fn discover_dim(target: &str) -> Result<usize> {
    let mut conn = ClientConn::connect(target, CONNECT_TIMEOUT)?;
    let resp = conn.request("GET", "/models", b"")?;
    if resp.status != 200 {
        return Err(Error::Service(format!(
            "GET /models answered {}",
            resp.status
        )));
    }
    let v = resp.json()?;
    let serving = v.req_str("serving")?.to_string();
    let models = v
        .req("models")?
        .as_arr()
        .ok_or_else(|| Error::Parse("'models' is not an array".into()))?;
    for m in models {
        if m.req_str("name")? == serving {
            return m.req_usize("dim");
        }
    }
    Err(Error::Service(format!(
        "serving model '{serving}' not in the registry listing"
    )))
}

/// Per-shard partial tally, merged by [`run`].
#[derive(Default)]
struct ShardTally {
    requests_ok: u64,
    rejected: u64,
    deadline_504: u64,
    retries: u64,
    errors: u64,
    overruns: u64,
    rows_ok: u64,
    latency_us: Histogram,
}

/// One multiplexed client connection inside a shard.
struct Slot {
    stream: Option<TcpStream>,
    reader: ResponseReader,
    write_buf: Vec<u8>,
    write_at: usize,
    /// A request is written (or being written) and its response has
    /// not arrived yet.
    in_flight: bool,
    t_start: Instant,
    requests_left: usize,
    /// Under `--retry`: when set, the slot is parked until this
    /// instant, then re-issues the rejected request.
    retry_at: Option<Instant>,
    /// Retries consumed by the current request (reset on completion).
    attempts: u32,
    rng: Pcg64,
}

impl Slot {
    fn idle(&self) -> bool {
        !self.in_flight && self.requests_left > 0 && self.retry_at.is_none()
    }

    fn wants_write(&self) -> bool {
        self.write_at < self.write_buf.len()
    }

    /// Drop the connection after a transport failure; the slot
    /// reconnects on its next issued request.
    fn fail(&mut self, tally: &mut ShardTally) {
        tally.errors += 1;
        self.requests_left = self.requests_left.saturating_sub(1);
        self.stream = None;
        self.reader = ResponseReader::new();
        self.write_buf.clear();
        self.write_at = 0;
        self.in_flight = false;
        self.retry_at = None;
        self.attempts = 0;
    }
}

/// Run the load generation described by `cfg`.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    if cfg.clients == 0 || cfg.requests_per_client == 0 {
        return Err(Error::Config(
            "loadgen needs >= 1 client and >= 1 request".into(),
        ));
    }
    if cfg.rows_per_request == 0 {
        return Err(Error::Config(
            "loadgen needs >= 1 row per request".into(),
        ));
    }
    let target = normalize_target(&cfg.target);
    wait_healthy(&target, Duration::from_millis(cfg.warmup_ms))?;
    let dim =
        if cfg.dim > 0 { cfg.dim } else { discover_dim(&target)? };
    let sock = target
        .to_socket_addrs()
        .map_err(|e| Error::Io(format!("resolve {target}: {e}")))?
        .next()
        .ok_or_else(|| {
            Error::Io(format!("{target}: no usable address"))
        })?;

    let shards = cfg
        .clients
        .div_ceil(CONNS_PER_SHARD)
        .clamp(1, MAX_SHARDS);
    let per_shard = cfg.clients.div_ceil(shards);
    let t0 = Instant::now();
    let stop = Arc::new(AtomicBool::new(false));
    let poller = if cfg.metrics_poll_s > 0 {
        let target = target.clone();
        let period = Duration::from_secs(cfg.metrics_poll_s);
        let stop = stop.clone();
        let h = std::thread::Builder::new()
            .name("rskpca-loadgen-poll".into())
            .spawn(move || metrics_poll_loop(&target, period, t0, &stop))
            .map_err(|e| {
                Error::Service(format!("spawn metrics poller: {e}"))
            })?;
        Some(h)
    } else {
        None
    };
    let mut threads = Vec::with_capacity(shards);
    for shard in 0..shards {
        let lo = shard * per_shard;
        let hi = (lo + per_shard).min(cfg.clients);
        if lo >= hi {
            break;
        }
        let cfg = cfg.clone();
        let rate = cfg.rate / shards as f64;
        let h = std::thread::Builder::new()
            .name(format!("rskpca-loadgen-{shard}"))
            .spawn(move || shard_loop(&cfg, sock, dim, lo..hi, rate))
            .map_err(|e| {
                Error::Service(format!("spawn loadgen shard: {e}"))
            })?;
        threads.push(h);
    }
    let mut report = LoadgenReport {
        clients: cfg.clients,
        ..Default::default()
    };
    for t in threads {
        let part = t.join().map_err(|_| {
            Error::Service("loadgen shard panicked".into())
        })?;
        report.requests_ok += part.requests_ok;
        report.rejected += part.rejected;
        report.deadline_504 += part.deadline_504;
        report.retries += part.retries;
        report.errors += part.errors;
        report.overruns += part.overruns;
        report.rows_ok += part.rows_ok;
        report.latency_us.merge(&part.latency_us);
    }
    stop.store(true, Ordering::Relaxed);
    if let Some(p) = poller {
        let (samples, errors) = p.join().map_err(|_| {
            Error::Service("metrics poller panicked".into())
        })?;
        report.metrics_samples = samples;
        report.metrics_errors = errors;
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

/// Scrape `GET /metrics` every `period` until `stop`; always takes one
/// final scrape on the way out so even a short run yields a sample.
/// Returns the captured samples and the failed-scrape count.
fn metrics_poll_loop(
    target: &str,
    period: Duration,
    t0: Instant,
    stop: &AtomicBool,
) -> (Vec<MetricsSample>, u64) {
    let mut samples = Vec::new();
    let mut errors = 0u64;
    let mut next = Instant::now();
    loop {
        let stopping = stop.load(Ordering::Relaxed);
        if stopping || Instant::now() >= next {
            match scrape_metrics(target, t0) {
                Ok(s) => samples.push(s),
                Err(_) => errors += 1,
            }
            next += period;
        }
        if stopping {
            return (samples, errors);
        }
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// One strict scrape: fetch, require 200, run the full-format parser,
/// pull out the headline series.
fn scrape_metrics(
    target: &str,
    t0: Instant,
) -> Result<MetricsSample> {
    let mut conn = ClientConn::connect(target, CONNECT_TIMEOUT)?;
    let resp = conn.request("GET", "/metrics", b"")?;
    if resp.status != 200 {
        return Err(Error::Service(format!(
            "GET /metrics answered {}",
            resp.status
        )));
    }
    let text = std::str::from_utf8(&resp.body)
        .map_err(|_| Error::Parse("non-utf8 /metrics body".into()))?;
    let parsed = prom::parse(text).map_err(Error::Parse)?;
    let value = |name: &str| parsed.value(name).unwrap_or(0.0);
    Ok(MetricsSample {
        t_s: t0.elapsed().as_secs_f64(),
        requests_total: value("rskpca_requests_total"),
        conns_open: value("rskpca_http_conns_open"),
        requests_1m: value("rskpca_requests_1m"),
        series: parsed.samples.len(),
    })
}

/// Drive one shard's connections to completion.
fn shard_loop(
    cfg: &LoadgenConfig,
    sock: std::net::SocketAddr,
    dim: usize,
    ids: std::ops::Range<usize>,
    rate: f64,
) -> ShardTally {
    let mut tally = ShardTally::default();
    let mut slots: Vec<Slot> = ids
        .map(|id| Slot {
            stream: None,
            reader: ResponseReader::new(),
            write_buf: Vec::new(),
            write_at: 0,
            in_flight: false,
            t_start: Instant::now(),
            requests_left: cfg.requests_per_client,
            retry_at: None,
            attempts: 0,
            rng: Pcg64::new(
                cfg.seed
                    ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ),
        })
        .collect();

    // Closed loop: every slot starts a request immediately.  Open
    // loop: requests fire on the shard's share of the global rate.
    let open_loop = rate > 0.0;
    let interval = if open_loop {
        Duration::from_secs_f64(1.0 / rate)
    } else {
        Duration::ZERO
    };
    let mut next_fire = Instant::now();
    let mut fds: Vec<PollFd> = Vec::new();
    let mut fd_slot: Vec<usize> = Vec::new();
    loop {
        if slots.iter().all(|s| s.requests_left == 0) {
            return tally;
        }
        // Parked retries re-issue as soon as their backoff elapses.
        // A retransmit of an admitted-then-rejected request, so it
        // does not consume an open-loop tick.
        let now = Instant::now();
        for s in slots.iter_mut() {
            if s.retry_at.is_some_and(|at| at <= now) {
                s.retry_at = None;
                issue(s, cfg, sock, dim, &mut tally);
            }
        }
        if open_loop {
            // Fire every due tick; overrun when no slot is free to
            // carry it.
            let now = Instant::now();
            while next_fire <= now {
                next_fire += interval;
                match slots.iter_mut().find(|s| s.idle()) {
                    Some(s) => issue(s, cfg, sock, dim, &mut tally),
                    None => tally.overruns += 1,
                }
            }
        } else {
            // Closed loop: every idle slot with work left starts its
            // next request (covers startup, completions, and
            // reconnects after a transport failure alike).
            for s in slots.iter_mut() {
                if s.idle() {
                    issue(s, cfg, sock, dim, &mut tally);
                }
            }
        }

        fds.clear();
        fd_slot.clear();
        for (i, s) in slots.iter().enumerate() {
            let Some(stream) = &s.stream else { continue };
            let mut ev = 0i16;
            if s.wants_write() {
                ev |= POLLOUT;
            } else if s.in_flight {
                ev |= POLLIN;
            }
            if ev != 0 {
                fds.push(PollFd::new(stream_fd(stream), ev));
                fd_slot.push(i);
            }
        }
        let timeout = if open_loop {
            let until = next_fire
                .saturating_duration_since(Instant::now())
                .as_millis() as i32;
            until.clamp(0, 10)
        } else {
            10
        };
        let _ = poll_fds(&mut fds, timeout);
        for (k, f) in fds.iter().enumerate() {
            let i = fd_slot[k];
            if f.writable() && slots[i].wants_write() {
                advance_write(&mut slots[i], &mut tally);
            }
            if f.readable() && slots[i].in_flight {
                advance_read(&mut slots[i], cfg, &mut tally);
            }
        }
    }
}

/// Start one request on an idle slot (connecting first if needed).
fn issue(
    s: &mut Slot,
    cfg: &LoadgenConfig,
    sock: std::net::SocketAddr,
    dim: usize,
    tally: &mut ShardTally,
) {
    if s.stream.is_none() {
        match TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT) {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                let _ = stream.set_nonblocking(true);
                s.stream = Some(stream);
            }
            Err(_) => {
                tally.errors += 1;
                s.requests_left = s.requests_left.saturating_sub(1);
                return;
            }
        }
    }
    let body =
        random_rows_body(&mut s.rng, cfg.rows_per_request, dim);
    s.write_buf.clear();
    s.write_at = 0;
    use std::fmt::Write as _;
    let mut head = String::with_capacity(96);
    let _ = write!(
        head,
        "POST /embed HTTP/1.1\r\nhost: rskpca\r\n\
         content-type: application/json\r\n\
         content-length: {}\r\n\r\n",
        body.len()
    );
    s.write_buf.extend_from_slice(head.as_bytes());
    s.write_buf.extend_from_slice(body.as_bytes());
    s.in_flight = true;
    s.t_start = Instant::now();
    advance_write(s, tally);
}

/// Push buffered request bytes until the socket would block.
fn advance_write(s: &mut Slot, tally: &mut ShardTally) {
    let Some(stream) = &mut s.stream else { return };
    while s.write_at < s.write_buf.len() {
        match stream.write(&s.write_buf[s.write_at..]) {
            Ok(0) => return s.fail(tally),
            Ok(n) => s.write_at += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                return;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(_) => return s.fail(tally),
        }
    }
    s.write_buf.clear();
    s.write_at = 0;
}

/// Drain readable response bytes; a complete response is recorded
/// and frees the slot (the shard loop issues its next request).
fn advance_read(
    s: &mut Slot,
    cfg: &LoadgenConfig,
    tally: &mut ShardTally,
) {
    let mut tmp = [0u8; 4096];
    loop {
        let Some(stream) = &mut s.stream else { return };
        match stream.read(&mut tmp) {
            Ok(0) => return s.fail(tally),
            Ok(n) => {
                s.reader.push_bytes(&tmp[..n]);
                match s.reader.try_next() {
                    Ok(Some(resp)) => {
                        s.in_flight = false;
                        match resp.status {
                            200 => {
                                s.requests_left =
                                    s.requests_left.saturating_sub(1);
                                s.attempts = 0;
                                tally.requests_ok += 1;
                                tally.rows_ok +=
                                    cfg.rows_per_request as u64;
                                tally.latency_us.record(
                                    s.t_start
                                        .elapsed()
                                        .as_secs_f64()
                                        * 1e6,
                                );
                            }
                            429 | 503
                                if cfg.retry
                                    && s.attempts < MAX_RETRIES =>
                            {
                                // Park the slot and re-send after the
                                // server's backoff hint plus jitter;
                                // the request is not consumed.
                                s.attempts += 1;
                                tally.retries += 1;
                                let base = retry_hint_ms(&resp);
                                let jitter =
                                    s.rng.below(base as usize / 2 + 1)
                                        as u64;
                                s.retry_at = Some(
                                    Instant::now()
                                        + Duration::from_millis(
                                            base + jitter,
                                        ),
                                );
                            }
                            429 => {
                                s.requests_left =
                                    s.requests_left.saturating_sub(1);
                                s.attempts = 0;
                                tally.rejected += 1;
                            }
                            504 => {
                                s.requests_left =
                                    s.requests_left.saturating_sub(1);
                                s.attempts = 0;
                                tally.deadline_504 += 1;
                            }
                            _ => {
                                s.requests_left =
                                    s.requests_left.saturating_sub(1);
                                s.attempts = 0;
                                tally.errors += 1;
                            }
                        }
                        return;
                    }
                    Ok(None) => {} // need more bytes
                    Err(_) => return s.fail(tally),
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                return;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(_) => return s.fail(tally),
        }
    }
}

/// Backoff hint of a 429/503 response, milliseconds.  Prefers the
/// body's millisecond-precision `retry_after_ms` field, falls back to
/// the coarser `Retry-After` header (whole seconds), then to
/// [`RETRY_FALLBACK_MS`].
fn retry_hint_ms(resp: &ClientResponse) -> u64 {
    if let Ok(v) = resp.json() {
        if let Some(ms) =
            v.get("retry_after_ms").and_then(|m| m.as_f64())
        {
            if ms >= 0.0 {
                return ms as u64;
            }
        }
    }
    resp.header("retry-after")
        .and_then(|v| v.trim().parse::<u64>().ok())
        .map(|secs| secs.saturating_mul(1000))
        .unwrap_or(RETRY_FALLBACK_MS)
}

/// A `{"rows": [[...], ...]}` body of standard-normal rows.
fn random_rows_body(
    rng: &mut Pcg64,
    rows: usize,
    dim: usize,
) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(16 + rows * dim * 10);
    s.push_str("{\"rows\":[");
    for i in 0..rows {
        if i > 0 {
            s.push(',');
        }
        s.push('[');
        for j in 0..dim {
            if j > 0 {
                s.push(',');
            }
            let _ = write!(s, "{:.6}", rng.normal());
        }
        s.push(']');
    }
    s.push_str("]}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_normalization() {
        assert_eq!(normalize_target("127.0.0.1:80"), "127.0.0.1:80");
        assert_eq!(
            normalize_target("http://127.0.0.1:80/"),
            "127.0.0.1:80"
        );
    }

    #[test]
    fn body_generator_emits_valid_json() {
        let mut rng = Pcg64::new(7);
        let body = random_rows_body(&mut rng, 3, 2);
        let v = crate::ser::parse(&body).unwrap();
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn report_renders_without_samples() {
        let mut r = LoadgenReport::default();
        let text = r.render();
        assert!(text.contains("0 ok"));
    }

    #[test]
    fn report_json_has_percentile_fields() {
        let mut r = LoadgenReport::default();
        r.latency_us.record(100.0);
        r.latency_us.record(200.0);
        let j = r.to_json();
        assert!(j.get("latency_p50_us").is_some());
        assert!(j.get("latency_p99_us").is_some());
        assert!(j.get("overruns").is_some());
        assert!(j.get("retries").is_some());
        assert!(j.get("deadline_504").is_some());
    }

    #[test]
    fn retry_hint_prefers_body_ms_over_header_seconds() {
        let with_body = ClientResponse {
            status: 429,
            headers: vec![("retry-after".into(), "1".into())],
            body: br#"{"error":"x","status":429,"retry_after_ms":250}"#
                .to_vec(),
        };
        assert_eq!(retry_hint_ms(&with_body), 250);
        let header_only = ClientResponse {
            status: 503,
            headers: vec![("retry-after".into(), "2".into())],
            body: b"busy".to_vec(),
        };
        assert_eq!(retry_hint_ms(&header_only), 2000);
        let bare = ClientResponse {
            status: 503,
            headers: Vec::new(),
            body: Vec::new(),
        };
        assert_eq!(retry_hint_ms(&bare), RETRY_FALLBACK_MS);
    }

    #[test]
    fn retried_and_shed_outcomes_render_separately() {
        let mut r = LoadgenReport {
            requests_ok: 5,
            rejected: 1,
            deadline_504: 2,
            retries: 3,
            ..Default::default()
        };
        let text = r.render();
        assert!(text.contains("8 requests"), "{text}");
        assert!(text.contains("2 deadline (504)"), "{text}");
        assert!(text.contains("3 retries"), "{text}");
    }

    #[test]
    fn config_validation() {
        let cfg = LoadgenConfig { clients: 0, ..Default::default() };
        assert!(run(&cfg).is_err());
    }
}
