//! Closed-loop load generator: N client threads, each holding one
//! keep-alive connection and replaying `POST /embed` batches
//! back-to-back (a new request is issued only after the previous reply
//! lands — so offered load adapts to service capacity instead of
//! overrunning it).  Aggregates per-thread latency histograms into a
//! throughput / percentile report; 429s are counted separately from
//! hard errors, making admission control directly observable.
//!
//! Used by the `rskpca loadgen` CLI subcommand, the CI smoke step, the
//! loopback integration tests, and `benches/bench_serving.rs`.

use std::time::{Duration, Instant};

use super::http::ClientConn;
use crate::error::{Error, Result};
use crate::metrics::Histogram;
use crate::prng::Pcg64;

/// Connect timeout for each client connection.
const CONNECT_TIMEOUT: Duration = Duration::from_millis(2000);

/// Load-generator knobs.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address: "host:port" (an `http://` prefix is tolerated).
    pub target: String,
    /// Concurrent closed-loop client threads.
    pub clients: usize,
    /// Requests each client issues.
    pub requests_per_client: usize,
    /// Rows per `POST /embed` request.
    pub rows_per_request: usize,
    /// Feature dimension of generated rows; 0 = discover from
    /// `GET /models`.
    pub dim: usize,
    /// PRNG seed (each client derives its own stream).
    pub seed: u64,
    /// How long to poll `GET /healthz` before giving up.
    pub warmup_ms: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            target: "127.0.0.1:7878".into(),
            clients: 4,
            requests_per_client: 50,
            rows_per_request: 8,
            dim: 0,
            seed: 0x10AD,
            warmup_ms: 5000,
        }
    }
}

/// Aggregated results of one load-generation run.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    pub clients: usize,
    pub requests_ok: u64,
    /// 429 responses (admission control working as designed).
    pub rejected: u64,
    /// Transport failures and non-200/429 statuses.
    pub errors: u64,
    pub rows_ok: u64,
    pub wall_s: f64,
    /// End-to-end request latency of successful requests, microseconds.
    pub latency_us: Histogram,
}

impl LoadgenReport {
    /// Successful rows per second of wall time.
    pub fn rows_per_s(&self) -> f64 {
        self.rows_ok as f64 / self.wall_s.max(1e-9)
    }

    /// Successful requests per second of wall time.
    pub fn requests_per_s(&self) -> f64 {
        self.requests_ok as f64 / self.wall_s.max(1e-9)
    }

    /// Multi-line human-readable report.
    pub fn render(&mut self) -> String {
        let total = self.requests_ok + self.rejected + self.errors;
        let max_us = if self.latency_us.is_empty() {
            0.0
        } else {
            self.latency_us.max()
        };
        format!(
            "loadgen: {total} requests from {} clients in {:.3}s — \
             {} ok, {} rejected (429), {} errors\n\
             throughput: {:.0} rows/s ({:.1} req/s)\n\
             latency: mean={:.0}us p50={:.0}us p95={:.0}us \
             p99={:.0}us max={:.0}us",
            self.clients,
            self.wall_s,
            self.requests_ok,
            self.rejected,
            self.errors,
            self.rows_per_s(),
            self.requests_per_s(),
            self.latency_us.mean(),
            self.latency_us.percentile(50.0),
            self.latency_us.percentile(95.0),
            self.latency_us.p99(),
            max_us,
        )
    }
}

/// Accept "host:port", "http://host:port" or a trailing slash.
pub fn normalize_target(target: &str) -> String {
    let t = target.strip_prefix("http://").unwrap_or(target);
    t.trim_end_matches('/').to_string()
}

/// Poll `GET /healthz` until it answers 200 or `budget` expires.
pub fn wait_healthy(target: &str, budget: Duration) -> Result<()> {
    let deadline = Instant::now() + budget;
    loop {
        if let Ok(mut conn) =
            ClientConn::connect(target, Duration::from_millis(250))
        {
            if let Ok(resp) = conn.request("GET", "/healthz", b"") {
                if resp.status == 200 {
                    return Ok(());
                }
            }
        }
        if Instant::now() >= deadline {
            return Err(Error::Service(format!(
                "server at {target} not healthy within {budget:?}"
            )));
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// Discover the serving model's feature dimension via `GET /models`.
pub fn discover_dim(target: &str) -> Result<usize> {
    let mut conn = ClientConn::connect(target, CONNECT_TIMEOUT)?;
    let resp = conn.request("GET", "/models", b"")?;
    if resp.status != 200 {
        return Err(Error::Service(format!(
            "GET /models answered {}",
            resp.status
        )));
    }
    let v = resp.json()?;
    let serving = v.req_str("serving")?.to_string();
    let models = v
        .req("models")?
        .as_arr()
        .ok_or_else(|| Error::Parse("'models' is not an array".into()))?;
    for m in models {
        if m.req_str("name")? == serving {
            return m.req_usize("dim");
        }
    }
    Err(Error::Service(format!(
        "serving model '{serving}' not in the registry listing"
    )))
}

/// Per-client partial tally, merged by [`run`].
#[derive(Default)]
struct ClientTally {
    requests_ok: u64,
    rejected: u64,
    errors: u64,
    rows_ok: u64,
    latency_us: Histogram,
}

/// Run the closed-loop load generation described by `cfg`.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    if cfg.clients == 0 || cfg.requests_per_client == 0 {
        return Err(Error::Config(
            "loadgen needs >= 1 client and >= 1 request".into(),
        ));
    }
    if cfg.rows_per_request == 0 {
        return Err(Error::Config(
            "loadgen needs >= 1 row per request".into(),
        ));
    }
    let target = normalize_target(&cfg.target);
    wait_healthy(&target, Duration::from_millis(cfg.warmup_ms))?;
    let dim =
        if cfg.dim > 0 { cfg.dim } else { discover_dim(&target)? };
    let t0 = Instant::now();
    let mut threads = Vec::with_capacity(cfg.clients);
    for client in 0..cfg.clients {
        let target = target.clone();
        let cfg = cfg.clone();
        threads.push(std::thread::spawn(move || {
            client_loop(&target, &cfg, dim, client as u64)
        }));
    }
    let mut report = LoadgenReport {
        clients: cfg.clients,
        ..Default::default()
    };
    for t in threads {
        let part = t.join().map_err(|_| {
            Error::Service("loadgen client panicked".into())
        })?;
        report.requests_ok += part.requests_ok;
        report.rejected += part.rejected;
        report.errors += part.errors;
        report.rows_ok += part.rows_ok;
        report.latency_us.merge(&part.latency_us);
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    Ok(report)
}

fn client_loop(
    target: &str,
    cfg: &LoadgenConfig,
    dim: usize,
    client: u64,
) -> ClientTally {
    let mut tally = ClientTally::default();
    let mut rng = Pcg64::new(
        cfg.seed ^ client.wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let mut conn: Option<ClientConn> = None;
    for _ in 0..cfg.requests_per_client {
        let body =
            random_rows_body(&mut rng, cfg.rows_per_request, dim);
        if conn.is_none() {
            conn = ClientConn::connect(target, CONNECT_TIMEOUT).ok();
            if conn.is_none() {
                tally.errors += 1;
                continue;
            }
        }
        let t = Instant::now();
        let resp = conn
            .as_mut()
            .expect("connection established above")
            .request("POST", "/embed", body.as_bytes());
        match resp {
            Ok(r) if r.status == 200 => {
                tally.requests_ok += 1;
                tally.rows_ok += cfg.rows_per_request as u64;
                tally
                    .latency_us
                    .record(t.elapsed().as_secs_f64() * 1e6);
            }
            Ok(r) if r.status == 429 => tally.rejected += 1,
            Ok(_) => tally.errors += 1,
            Err(_) => {
                // Transport failure: drop the connection and let the
                // next iteration reconnect.
                tally.errors += 1;
                conn = None;
            }
        }
    }
    tally
}

/// A `{"rows": [[...], ...]}` body of standard-normal rows.
fn random_rows_body(
    rng: &mut Pcg64,
    rows: usize,
    dim: usize,
) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(16 + rows * dim * 10);
    s.push_str("{\"rows\":[");
    for i in 0..rows {
        if i > 0 {
            s.push(',');
        }
        s.push('[');
        for j in 0..dim {
            if j > 0 {
                s.push(',');
            }
            let _ = write!(s, "{:.6}", rng.normal());
        }
        s.push(']');
    }
    s.push_str("]}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_normalization() {
        assert_eq!(normalize_target("127.0.0.1:80"), "127.0.0.1:80");
        assert_eq!(
            normalize_target("http://127.0.0.1:80/"),
            "127.0.0.1:80"
        );
    }

    #[test]
    fn body_generator_emits_valid_json() {
        let mut rng = Pcg64::new(7);
        let body = random_rows_body(&mut rng, 3, 2);
        let v = crate::ser::parse(&body).unwrap();
        let rows = v.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn report_renders_without_samples() {
        let mut r = LoadgenReport::default();
        let text = r.render();
        assert!(text.contains("0 ok"));
    }

    #[test]
    fn config_validation() {
        let cfg = LoadgenConfig { clients: 0, ..Default::default() };
        assert!(run(&cfg).is_err());
    }
}
