//! Readiness notification for the event loop: a two-declaration shim
//! over the C runtime's `poll(2)` entry point (already linked into
//! every Rust binary), in the style of the `signal` shim in
//! [`super::signal`] — together with the SIMD micro-kernels
//! (`linalg/simd.rs`) and the parallel pool's lifetime-erasing cast,
//! they form the crate's entire `unsafe` inventory.
//!
//! The interface is deliberately minimal: the caller builds a slice of
//! [`PollFd`] interest records each cycle (level-triggered, like the
//! syscall itself) and [`poll_fds`] fills in `revents`.  No registration
//! state, no edge semantics, no wakeup tokens — at the connection
//! counts this server targets (thousands), rebuilding the interest
//! array per cycle is noise next to one batched GEMM, and
//! level-triggered readiness makes the per-connection state machines
//! re-entrant by construction: a handler that stops mid-message is
//! simply woken again on the next cycle.
//!
//! Non-unix fallback: [`poll_fds`] degrades to "sleep briefly, report
//! everything ready".  Spurious readiness is harmless because every
//! socket the event loop owns is non-blocking — a not-actually-ready fd
//! just returns `WouldBlock` — so the loop stays correct and merely
//! burns a few syscalls; real deployments of the serving layer are
//! unix-hosted.

/// Interest/readiness record, ABI-compatible with `struct pollfd`.
///
/// The field layout (`int fd; short events; short revents;`) is fixed
/// by POSIX and identical on every unix the crate targets.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    /// File descriptor to watch (ignored by the non-unix fallback).
    pub fd: i32,
    /// Requested events ([`POLLIN`] | [`POLLOUT`]).
    pub events: i16,
    /// Returned events (filled in by [`poll_fds`]); may also carry
    /// [`POLLERR`] / [`POLLHUP`] / [`POLLNVAL`] unrequested.
    pub revents: i16,
}

impl PollFd {
    /// An interest record for `fd` with the given event mask.
    pub fn new(fd: i32, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }

    /// Any readable-ish readiness: data, error, or hangup all mean
    /// "calling read() now will not block" (it returns bytes, an
    /// error, or EOF respectively).
    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0
    }

    /// Writable readiness (or an error, which a write will surface).
    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLERR | POLLHUP | POLLNVAL) != 0
    }
}

/// Data may be read without blocking.
pub const POLLIN: i16 = 0x001;
/// Data may be written without blocking.
pub const POLLOUT: i16 = 0x004;
/// Error condition (returned only).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (returned only).
pub const POLLHUP: i16 = 0x010;
/// Invalid fd (returned only).
pub const POLLNVAL: i16 = 0x020;

/// Wait up to `timeout_ms` for readiness on `fds`, filling `revents`.
/// Returns the number of records with non-zero `revents`.  A signal
/// interruption (EINTR) is reported as `Ok(0)` — the event loop treats
/// it like a timeout and re-evaluates its world, which is exactly what
/// a shutdown signal needs.
#[cfg(unix)]
pub fn poll_fds(
    fds: &mut [PollFd],
    timeout_ms: i32,
) -> std::io::Result<usize> {
    extern "C" {
        // `int poll(struct pollfd *fds, nfds_t nfds, int timeout)`;
        // nfds_t is pointer-sized on the targets we build for.
        fn poll(
            fds: *mut PollFd,
            nfds: std::ffi::c_ulong,
            timeout: i32,
        ) -> i32;
    }
    if fds.is_empty() {
        // poll(2) with nfds = 0 is just a sleep; do it in std.
        if timeout_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(
                timeout_ms as u64,
            ));
        }
        return Ok(0);
    }
    for f in fds.iter_mut() {
        f.revents = 0;
    }
    let n = unsafe {
        poll(
            fds.as_mut_ptr(),
            fds.len() as std::ffi::c_ulong,
            timeout_ms,
        )
    };
    if n < 0 {
        let err = std::io::Error::last_os_error();
        if err.kind() == std::io::ErrorKind::Interrupted {
            return Ok(0);
        }
        return Err(err);
    }
    Ok(n as usize)
}

/// Non-unix fallback: sleep briefly, then report every requested event
/// as ready.  Safe because all event-loop I/O is non-blocking (see the
/// module docs); costs spurious `WouldBlock` syscalls, not correctness.
#[cfg(not(unix))]
pub fn poll_fds(
    fds: &mut [PollFd],
    timeout_ms: i32,
) -> std::io::Result<usize> {
    let ms = timeout_ms.clamp(0, 5) as u64;
    if ms > 0 {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
    let mut ready = 0;
    for f in fds.iter_mut() {
        f.revents = f.events;
        if f.revents != 0 {
            ready += 1;
        }
    }
    Ok(ready)
}

/// Raw fd of a listener, for the poll set.
#[cfg(unix)]
pub fn listener_fd(l: &std::net::TcpListener) -> i32 {
    use std::os::unix::io::AsRawFd;
    l.as_raw_fd()
}

/// Raw fd of a stream, for the poll set.
#[cfg(unix)]
pub fn stream_fd(s: &std::net::TcpStream) -> i32 {
    use std::os::unix::io::AsRawFd;
    s.as_raw_fd()
}

/// Non-unix: the fallback `poll_fds` never inspects fds.
#[cfg(not(unix))]
pub fn listener_fd(_l: &std::net::TcpListener) -> i32 {
    -1
}

/// Non-unix: the fallback `poll_fds` never inspects fds.
#[cfg(not(unix))]
pub fn stream_fd(_s: &std::net::TcpStream) -> i32 {
    -1
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn empty_set_times_out_cleanly() {
        let t0 = std::time::Instant::now();
        let n = poll_fds(&mut [], 20).unwrap();
        assert_eq!(n, 0);
        assert!(t0.elapsed().as_millis() >= 15);
    }

    #[test]
    fn listener_becomes_readable_on_pending_connect() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut fds =
            [PollFd::new(listener_fd(&listener), POLLIN)];
        // Nothing pending yet: times out un-ready (the non-unix
        // fallback reports spuriously ready, which is also allowed by
        // the poll contract the loop is written against).
        let _ = poll_fds(&mut fds, 10).unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let n = poll_fds(&mut fds, 1000).unwrap();
        assert!(n >= 1);
        assert!(fds[0].readable());
        let (_s, _) = listener.accept().unwrap();
    }

    #[test]
    fn stream_readiness_tracks_data_and_eof() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();

        // A fresh healthy socket is writable.
        let mut fds =
            [PollFd::new(stream_fd(&server_side), POLLOUT)];
        let n = poll_fds(&mut fds, 1000).unwrap();
        assert!(n >= 1 && fds[0].writable());

        // Data arrival flips POLLIN.
        client.write_all(b"ping").unwrap();
        let mut fds =
            [PollFd::new(stream_fd(&server_side), POLLIN)];
        let n = poll_fds(&mut fds, 1000).unwrap();
        assert!(n >= 1 && fds[0].readable());
        let mut s = server_side;
        let mut buf = [0u8; 16];
        assert_eq!(s.read(&mut buf).unwrap(), 4);

        // Peer close is also "readable" (read returns Ok(0)).
        drop(client);
        let mut fds = [PollFd::new(stream_fd(&s), POLLIN)];
        let n = poll_fds(&mut fds, 1000).unwrap();
        assert!(n >= 1 && fds[0].readable());
        assert_eq!(s.read(&mut buf).unwrap(), 0);
    }
}
