//! The network serving layer: a dependency-free HTTP/1.1 front end
//! over the coordinator's embedding service.
//!
//! ```text
//! clients ──► acceptor (non-blocking; 503 when the pending-connection
//!    │        queue overflows — the acceptor itself never blocks)
//!    │             │ bounded sync_channel(conn_backlog)
//!    ▼             ▼
//!  keep-alive   worker pool (cfg.workers connection handlers;
//!  connections  parse → route → respond, per-route latency recorded)
//!                    │
//!                POST /embed ──► ServiceHandle
//!                    │            queue_policy = reject: try_embed,
//!                    │              saturation → 429 + Retry-After
//!                    │            queue_policy = block: embed (waits)
//!                    ▼
//!            coordinator queue → dynamic batcher → backend
//! ```
//!
//! **Backpressure contract.**  Saturation surfaces at two levels, and
//! neither blocks the acceptor: (1) the coordinator's bounded embed
//! queue — under the default `reject` policy a full queue answers
//! `429 Too Many Requests` with a `Retry-After` hint, so a closed-loop
//! client backs off instead of stacking requests; (2) the bounded
//! pending-connection queue in front of the worker pool — when every
//! handler is busy and the backlog is full, the acceptor answers
//! `503 Service Unavailable` directly and closes.  Everything else
//! (parse errors, bad shapes, oversized bodies) is a per-request 4xx
//! on a connection that stays usable.
//!
//! The module is std-only, like the rest of the crate: hand-rolled
//! HTTP in [`http`], route handlers in `routes`, per-route metrics in
//! `stats`, signal-driven shutdown ([`install_shutdown_handler`] /
//! [`shutdown_requested`]), and a closed-loop client harness in
//! [`loadgen`].
//!
//! **Hot-loop allocation contract.**  Connection workers only parse,
//! enqueue, and format — the Gram/projection compute for `POST /embed`
//! runs on the coordinator's batch worker, whose `NativeBackend` owns a
//! reusable `kernel::Scratch` (norms, packed GEMM panels, Gram tiles).
//! Once warmed at the serving shapes, every compute buffer is reused
//! without growth (asserted via `Scratch::grow_events` in the test
//! suite); per-batch heap traffic is limited to the response buffers
//! plus O(compute-threads) fork/join bookkeeping — nothing scales with
//! the row count, and the batch Gram is never materialized.

pub mod http;
pub mod loadgen;
mod routes;
mod signal;
mod stats;

pub use signal::{
    install_shutdown_handler, request_shutdown, shutdown_requested,
};

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServerConfig;
use crate::coordinator::ServiceHandle;
use crate::error::{Error, Result};
use crate::linalg::Matrix;

use self::http::{HttpError, RequestReader, Response};
use self::stats::RouteStats;

/// Cap on concurrent 503-drain helper threads spawned by the acceptor
/// (beyond it, rejected sockets are dropped outright).
const MAX_DRAIN_THREADS: u64 = 32;

/// Total wall-clock budget for draining unread bytes before a close.
const DRAIN_BUDGET: Duration = Duration::from_millis(500);

/// Shared state every connection handler sees.
struct ServerState {
    handle: ServiceHandle,
    cfg: ServerConfig,
    routes: RouteStats,
    started: Instant,
    shutdown: Arc<AtomicBool>,
    conns_accepted: AtomicU64,
    conns_rejected: AtomicU64,
    /// Live 503-drain helper threads (bounded; see `accept_loop`).
    drain_threads: AtomicU64,
    /// Lossy tap feeding request rows to a background refresher
    /// (`serve --refresh N`); `None` when no refresher runs.
    refresh_feed: Option<Mutex<SyncSender<Matrix>>>,
}

impl ServerState {
    fn conns_accepted(&self) -> u64 {
        self.conns_accepted.load(Ordering::Relaxed)
    }

    fn conns_rejected(&self) -> u64 {
        self.conns_rejected.load(Ordering::Relaxed)
    }
}

/// The running HTTP front end: one non-blocking acceptor thread plus a
/// fixed pool of connection-handler threads, all serving through a
/// [`ServiceHandle`].  Dropping (or calling [`HttpServer::shutdown`])
/// runs the orderly teardown: acceptor close → pending-connection
/// drain → worker join.  The embedding service itself is owned by the
/// caller and outlives the front end.
pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `cfg.listen` and start serving requests against `handle`.
    pub fn start(
        handle: ServiceHandle,
        cfg: &ServerConfig,
    ) -> Result<HttpServer> {
        HttpServer::start_with_feed(handle, cfg, None)
    }

    /// [`HttpServer::start`] plus a lossy refresher tap: every
    /// `POST /embed` body is `try_send`-forwarded (clone) into `feed`,
    /// so a background [`crate::kpca::OnlineRskpca`] refresher can
    /// learn from live traffic and hot-swap the served model.
    pub fn start_with_feed(
        handle: ServiceHandle,
        cfg: &ServerConfig,
        feed: Option<SyncSender<Matrix>>,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.listen).map_err(|e| {
            Error::Io(format!("bind {}: {e}", cfg.listen))
        })?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Io(format!("local_addr: {e}")))?;
        // Non-blocking accept so the acceptor can poll the shutdown
        // flag; accepted streams are switched back to blocking.
        listener.set_nonblocking(true).map_err(|e| {
            Error::Io(format!("set_nonblocking: {e}"))
        })?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let state = Arc::new(ServerState {
            handle,
            cfg: cfg.clone(),
            routes: RouteStats::new(),
            started: Instant::now(),
            shutdown: shutdown.clone(),
            conns_accepted: AtomicU64::new(0),
            conns_rejected: AtomicU64::new(0),
            drain_threads: AtomicU64::new(0),
            refresh_feed: feed.map(Mutex::new),
        });
        let (conn_tx, conn_rx) =
            mpsc::sync_channel::<TcpStream>(cfg.conn_backlog);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut workers = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let rx = conn_rx.clone();
            let st = state.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("rskpca-http-{i}"))
                    .spawn(move || worker_loop(&rx, &st))
                    .map_err(|e| {
                        Error::Service(format!("spawn http worker: {e}"))
                    })?,
            );
        }
        let st = state.clone();
        let acceptor = std::thread::Builder::new()
            .name("rskpca-http-accept".into())
            .spawn(move || accept_loop(&listener, conn_tx, &st))
            .map_err(|e| {
                Error::Service(format!("spawn acceptor: {e}"))
            })?;
        Ok(HttpServer {
            addr,
            shutdown,
            acceptor: Some(acceptor),
            workers,
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Orderly teardown: stop accepting, drain pending connections,
    /// join every handler thread.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Accept until shutdown.  Never blocks on downstream capacity: a full
/// pending-connection queue is answered with an immediate 503.
fn accept_loop(
    listener: &TcpListener,
    conn_tx: SyncSender<TcpStream>,
    state: &Arc<ServerState>,
) {
    while !state.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                state
                    .conns_accepted
                    .fetch_add(1, Ordering::Relaxed);
                match conn_tx.try_send(stream) {
                    Ok(()) => {}
                    Err(mpsc::TrySendError::Full(stream)) => {
                        state
                            .conns_rejected
                            .fetch_add(1, Ordering::Relaxed);
                        let retry_s = ((state.cfg.retry_after_ms
                            + 999)
                            / 1000)
                            .max(1);
                        let resp = Response::error(
                            503,
                            "all connection handlers busy",
                        )
                        .with_header(
                            "retry-after",
                            &retry_s.to_string(),
                        );
                        // The client has usually already written its
                        // request; closing with those bytes unread
                        // would RST the 503 away (see
                        // `respond_and_close`).  Drain on a short
                        // throwaway thread so the acceptor itself
                        // never blocks — but bound the helpers and
                        // tolerate spawn failure: under a genuine
                        // connection flood, dropping the socket (an
                        // RST instead of a readable 503) beats
                        // unbounded threads or a dead acceptor.
                        let live = state
                            .drain_threads
                            .load(Ordering::Relaxed);
                        if live < MAX_DRAIN_THREADS {
                            state
                                .drain_threads
                                .fetch_add(1, Ordering::Relaxed);
                            let st = state.clone();
                            let spawned =
                                std::thread::Builder::new()
                                    .name("rskpca-http-503".into())
                                    .spawn(move || {
                                        respond_and_close(
                                            stream, &resp,
                                        );
                                        st.drain_threads.fetch_sub(
                                            1,
                                            Ordering::Relaxed,
                                        );
                                    });
                            if spawned.is_err() {
                                state.drain_threads.fetch_sub(
                                    1,
                                    Ordering::Relaxed,
                                );
                            }
                        }
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => return,
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                // Transient accept failure (e.g. EMFILE): back off
                // briefly instead of spinning.
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    // Dropping conn_tx ends the workers' recv loop once the pending
    // backlog drains.
}

/// Pull connections off the shared queue until the acceptor hangs up.
fn worker_loop(
    rx: &Arc<Mutex<Receiver<TcpStream>>>,
    state: &Arc<ServerState>,
) {
    loop {
        let conn = {
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(_) => return,
            };
            guard.recv()
        };
        match conn {
            Ok(stream) => handle_connection(stream, state),
            Err(_) => return,
        }
    }
}

/// Serve one keep-alive connection until it closes, errors, times out
/// idle, or the server shuts down (then the final response carries
/// `Connection: close`).
fn handle_connection(mut stream: TcpStream, state: &Arc<ServerState>) {
    let _ = stream.set_nodelay(true);
    // One timeout doubles as the idle keep-alive limit and a
    // slow-request bound, so a stalled client can't pin a worker.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(
        state.cfg.keep_alive_ms.max(1),
    )));
    let mut reader = RequestReader::new();
    loop {
        match reader
            .next_request(&mut stream, state.cfg.max_body_bytes)
        {
            Ok(req) => {
                let resp = routes::dispatch(state, &req);
                let close = !req.keep_alive()
                    || state.shutdown.load(Ordering::SeqCst);
                if resp.write_to(&mut stream, !close).is_err() {
                    return;
                }
                if close {
                    return;
                }
            }
            Err(HttpError::Closed) => return,
            Err(HttpError::Io(_)) => return,
            Err(HttpError::Bad { status, msg }) => {
                // Protocol-level violation: answer and close — the
                // byte stream can no longer be trusted to be framed.
                respond_and_close(
                    stream,
                    &Response::error(status, &msg),
                );
                return;
            }
        }
    }
}

/// Write a final response, then half-close and briefly drain unread
/// request bytes before dropping the socket.  Closing with unread
/// receive data makes the kernel RST the connection, which can destroy
/// an in-flight error response (e.g. a 413 sent before the body was
/// consumed); draining first lets the client actually read it.
fn respond_and_close(mut stream: TcpStream, resp: &Response) {
    use std::io::Read as _;
    if resp.write_to(&mut stream, false).is_err() {
        return;
    }
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream
        .set_read_timeout(Some(Duration::from_millis(200)));
    let deadline = Instant::now() + DRAIN_BUDGET;
    let mut scratch = [0u8; 4096];
    // Bounded drain — by bytes (256 KiB) *and* wall clock, so neither
    // a firehose nor a trickling client can pin the draining thread.
    for _ in 0..64 {
        if Instant::now() >= deadline {
            break;
        }
        match stream.read(&mut scratch) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
}
