//! The network serving layer: a dependency-free HTTP/1.1 front end
//! over the coordinator's embedding service, built on a non-blocking
//! `poll(2)` event loop.
//!
//! ```text
//! clients ──► listener (non-blocking, shared by every event thread)
//!    │             │ accept; over max_conns → 503 + close
//!    ▼             ▼
//!  keep-alive   event threads (cfg.workers; each owns the connections
//!  connections  it accepted and multiplexes them with poll(2))
//!    │               per-connection state machine:
//!    │               Reading ──parse──► dispatch
//!    │                  │                 ├─ Done ─► write buffer
//!    │                  │                 ├─ Pending ─► AwaitingReply
//!    │                  │                 └─ Blocked ─► AwaitingAdmission
//!    │               AwaitingReply ──try_recv──► write buffer ─► Reading
//!    ▼
//! POST /embed ──► ServiceHandle (try_embed; never blocks the loop)
//!                     ▼
//!        coordinator queue → size-OR-deadline batcher → backend
//! ```
//!
//! **Readiness vs blocking contract.**  Event threads never block on
//! anything but `poll` itself (bounded timeout): sockets are
//! non-blocking (`WouldBlock` returns to the loop), embed replies are
//! polled with `try_recv`, and the `block` queue policy parks the
//! *connection* in `AwaitingAdmission` rather than the thread.  One
//! slow, malicious, or silent client therefore costs one connection
//! slot, never a thread — the failure mode the old fixed worker pool
//! had (a stalled client pinned a whole worker) is structurally gone.
//!
//! **Backpressure contract.**  Saturation surfaces at two levels, and
//! neither blocks the loop: (1) the coordinator's bounded embed queue
//! — under the default `reject` policy a full queue answers `429 Too
//! Many Requests` with a `Retry-After` hint; (2) the connection cap
//! (`[server] max_conns`) — a connection over the cap is accepted,
//! answered `503 Service Unavailable`, and closed (far over the cap it
//! is dropped outright).  A client that stops *reading* is absorbed by
//! the per-connection write buffer plus kernel socket buffers, and
//! reaped by the idle timer once it stalls the response for
//! `keep_alive_ms`.
//!
//! **Idle reaping.**  `keep_alive_ms` bounds every externally-driven
//! wait: an idle keep-alive connection, a slow-loris drip feeding
//! partial request bytes, and a stalled never-reading response writer
//! are all closed once they make no *progress* (complete request
//! parsed, or response bytes accepted by the socket) for
//! `keep_alive_ms`.  Connections waiting on the server's own compute
//! (`AwaitingReply`) are exempt — that wait is bounded by the batcher's
//! deadline, not by client behavior.
//!
//! The module is std-only, like the rest of the crate: hand-rolled
//! HTTP in [`http`], the `poll(2)` shim in `event` (with `signal`, the
//! crate's entire unsafe inventory), route handlers in `routes`,
//! per-route metrics in `stats`, signal-driven shutdown
//! ([`install_shutdown_handler`] / [`shutdown_requested`]), and a
//! multiplexed open/closed-loop client harness in [`loadgen`].
//!
//! **Hot-loop allocation contract.**  Event threads only parse,
//! enqueue, and format — the Gram/projection compute for `POST /embed`
//! runs on the coordinator's batch worker, whose `NativeBackend` owns a
//! reusable `kernel::Scratch` (norms, packed GEMM panels, Gram tiles).
//! Once warmed at the serving shapes, every compute buffer is reused
//! without growth; per-connection buffers (read, write) shrink back to
//! empty after each message, so a long-lived idle connection holds only
//! the `Conn` bookkeeping itself.

mod event;
pub mod http;
pub mod loadgen;
mod routes;
mod signal;
mod stats;

pub use signal::{
    install_shutdown_handler, request_shutdown, shutdown_requested,
};

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServerConfig;
use crate::coordinator::ServiceHandle;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::obs::{Event, Obs};

use self::event::{
    listener_fd, poll_fds, stream_fd, PollFd, POLLIN, POLLOUT,
};
use self::http::{HttpError, RequestReader, Response};
use self::routes::Handled;
use self::stats::RouteStats;

/// Read granularity of the event loop.
const READ_CHUNK: usize = 16 * 1024;

/// Poll timeout when some connection awaits an embed reply or queue
/// admission: short, so replies are picked up promptly without a
/// wakeup channel.
const BUSY_POLL_MS: i32 = 1;

/// Poll timeout when fully idle; also the reap-check granularity.
const IDLE_POLL_MS: i32 = 25;

/// Accepts per thread per cycle — a connect flood cannot starve the
/// connections a thread already owns.
const ACCEPT_BURST: usize = 128;

/// Connections admitted past `max_conns` solely to be told "503":
/// beyond this slack the socket is dropped without a response.
const OVER_CAP_SLACK: u64 = 64;

/// How long a connection closed mid-protocol keeps draining unread
/// input so the final response isn't destroyed by a TCP reset.
const CLOSE_DRAIN: Duration = Duration::from_millis(250);

/// Grace period for in-flight requests at shutdown.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(2);

/// Shared state every event thread sees.
struct ServerState {
    handle: ServiceHandle,
    cfg: ServerConfig,
    routes: RouteStats,
    started: Instant,
    shutdown: Arc<AtomicBool>,
    conns_accepted: AtomicU64,
    conns_rejected: AtomicU64,
    /// Live connections across all event threads (the `max_conns`
    /// admission gate).
    conns_open: AtomicU64,
    /// Connection-id source for `http.conn.*` events.
    next_conn: AtomicU64,
    /// Observability handle shared with the coordinator (taken off the
    /// [`ServiceHandle`] so all layers record into one hub/ring).
    obs: Arc<Obs>,
    /// Lossy tap feeding request rows to a background refresher
    /// (`serve --refresh N`); `None` when no refresher runs.
    refresh_feed: Option<Mutex<SyncSender<Matrix>>>,
}

impl ServerState {
    fn conns_accepted(&self) -> u64 {
        self.conns_accepted.load(Ordering::Relaxed)
    }

    fn conns_rejected(&self) -> u64 {
        self.conns_rejected.load(Ordering::Relaxed)
    }

    fn conns_open(&self) -> u64 {
        self.conns_open.load(Ordering::Relaxed)
    }
}

/// What a connection is currently waiting on.
enum ConnPhase {
    /// Reading request bytes (or idle between keep-alive requests).
    Reading,
    /// Embed admitted to the coordinator; awaiting the reply receiver.
    /// The `bool` is the request's keep-alive decision.
    AwaitingReply(routes::PendingEmbed, bool),
    /// Parked on a saturated queue under the block policy.
    AwaitingAdmission(routes::BlockedEmbed, bool),
}

/// One multiplexed connection: socket, parser state, buffered partial
/// writes, and the timestamps the reaper keys off.
struct Conn {
    stream: TcpStream,
    reader: RequestReader,
    phase: ConnPhase,
    write_buf: Vec<u8>,
    write_at: usize,
    /// Server-wide connection id, carried by `http.conn.*` events.
    conn_id: u64,
    /// Start of the in-flight response write (enqueue time); taken on
    /// full drain to record the `write_us` stage histogram.
    resp_t0: Option<Instant>,
    /// Last *progress*: accept, a complete request parsed, or response
    /// bytes accepted by the socket.  Deliberately NOT refreshed by
    /// partial request reads — that is what bounds a slow-loris drip
    /// to `keep_alive_ms` total, instead of per-byte.
    last_progress: Instant,
    /// Close once the write buffer drains.
    close_after_write: bool,
    /// Framing is no longer trusted (protocol error / over-cap 503):
    /// read and discard input instead of parsing, so the final
    /// response isn't RST-destroyed by unread bytes at close.
    discard_input: bool,
    /// Deadline for the post-response drain of a `discard_input`
    /// connection.
    drain_until: Option<Instant>,
    /// Peer sent EOF; serve out what's in flight, accept nothing new.
    read_closed: bool,
    /// Back-reference for the [`Drop`]-based `conns_open` decrement.
    state: Arc<ServerState>,
}

/// `conns_open` is the `max_conns` admission gate, so it must stay
/// honest on *every* path a connection can die — including a panic
/// unwinding an event loop and dropping that thread's whole set before
/// the supervisor restarts it.  Tying the decrement to `Drop` makes
/// leaking a slot impossible by construction.
impl Drop for Conn {
    fn drop(&mut self) {
        self.state.conns_open.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Conn {
    fn new(
        stream: TcpStream,
        conn_id: u64,
        state: Arc<ServerState>,
    ) -> Conn {
        Conn {
            stream,
            reader: RequestReader::new(),
            phase: ConnPhase::Reading,
            write_buf: Vec::new(),
            write_at: 0,
            conn_id,
            resp_t0: None,
            last_progress: Instant::now(),
            close_after_write: false,
            discard_input: false,
            drain_until: None,
            read_closed: false,
            state,
        }
    }

    fn wants_write(&self) -> bool {
        self.write_at < self.write_buf.len()
    }

    /// Read interest: normal parsing only while in `Reading` with an
    /// empty write buffer (responses apply backpressure to pipelining);
    /// `discard_input` connections always read (to drain).
    fn wants_read(&self) -> bool {
        if self.read_closed {
            return false;
        }
        if self.discard_input {
            return true;
        }
        matches!(self.phase, ConnPhase::Reading) && !self.wants_write()
    }

    fn awaiting_service(&self) -> bool {
        matches!(
            self.phase,
            ConnPhase::AwaitingReply(..)
                | ConnPhase::AwaitingAdmission(..)
        )
    }

    /// Queue a response for writing; stamps the write-stage clock the
    /// `write_us` histogram is fed from at full drain.
    fn enqueue_response(&mut self, resp: &Response, keep_alive: bool) {
        if self.write_at > 0 {
            self.write_buf.drain(..self.write_at);
            self.write_at = 0;
        }
        self.write_buf
            .extend_from_slice(&resp.to_bytes(keep_alive));
        self.resp_t0 = Some(Instant::now());
        if !keep_alive {
            self.close_after_write = true;
        }
    }
}

/// The running HTTP front end: `cfg.workers` event threads, each
/// multiplexing the connections it accepted over `poll(2)`, all
/// serving through a [`ServiceHandle`].  Dropping (or calling
/// [`HttpServer::shutdown`]) runs the orderly teardown: stop
/// accepting → drain in-flight requests (bounded grace) → join.  The
/// embedding service itself is owned by the caller and outlives the
/// front end.
pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `cfg.listen` and start serving requests against `handle`.
    pub fn start(
        handle: ServiceHandle,
        cfg: &ServerConfig,
    ) -> Result<HttpServer> {
        HttpServer::start_with_feed(handle, cfg, None)
    }

    /// [`HttpServer::start`] plus a lossy refresher tap: every
    /// `POST /embed` body is `try_send`-forwarded (clone) into `feed`,
    /// so a background [`crate::kpca::OnlineRskpca`] refresher can
    /// learn from live traffic and hot-swap the served model.
    pub fn start_with_feed(
        handle: ServiceHandle,
        cfg: &ServerConfig,
        feed: Option<SyncSender<Matrix>>,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.listen).map_err(|e| {
            Error::Io(format!("bind {}: {e}", cfg.listen))
        })?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Io(format!("local_addr: {e}")))?;
        listener.set_nonblocking(true).map_err(|e| {
            Error::Io(format!("set_nonblocking: {e}"))
        })?;
        let listener = Arc::new(listener);
        let shutdown = Arc::new(AtomicBool::new(false));
        let obs = handle.obs();
        let state = Arc::new(ServerState {
            handle,
            cfg: cfg.clone(),
            routes: RouteStats::new(),
            started: Instant::now(),
            shutdown: shutdown.clone(),
            conns_accepted: AtomicU64::new(0),
            conns_rejected: AtomicU64::new(0),
            conns_open: AtomicU64::new(0),
            next_conn: AtomicU64::new(1),
            obs,
            refresh_feed: feed.map(Mutex::new),
        });
        let mut threads = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let l = listener.clone();
            let st = state.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("rskpca-http-{i}"))
                    .spawn(move || {
                        // Supervised: a panic in the event loop (a
                        // server bug — clients can't trigger one by
                        // protocol) drops that thread's connections,
                        // but the thread restarts with a fresh set
                        // instead of silently shrinking the pool.  A
                        // crash loop past the give-up threshold exits
                        // the process (crash-only posture).
                        let sup =
                            crate::sync::Supervisor::new("rskpca-http");
                        let obs = st.obs.clone();
                        sup.run(&obs, || event_loop(&l, &st));
                    })
                    .map_err(|e| {
                        Error::Service(format!(
                            "spawn event thread: {e}"
                        ))
                    })?,
            );
        }
        Ok(HttpServer { addr, shutdown, threads })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Orderly teardown: stop accepting, drain in-flight requests
    /// (bounded grace), join every event thread.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One event thread: poll the shared listener plus every connection
/// this thread owns, never blocking anywhere else.
fn event_loop(listener: &Arc<TcpListener>, state: &Arc<ServerState>) {
    let lfd = listener_fd(listener);
    let mut conns: Vec<Conn> = Vec::new();
    let mut fds: Vec<PollFd> = Vec::new();
    let mut fd_conn: Vec<usize> = Vec::new();
    let mut shutdown_since: Option<Instant> = None;

    loop {
        let shutting = state.shutdown.load(Ordering::SeqCst);
        if shutting && shutdown_since.is_none() {
            shutdown_since = Some(Instant::now());
        }

        // 1. Build the interest set.  `usize::MAX` marks the listener.
        fds.clear();
        fd_conn.clear();
        if !shutting {
            fds.push(PollFd::new(lfd, POLLIN));
            fd_conn.push(usize::MAX);
        }
        let mut busy = shutting;
        for (i, c) in conns.iter().enumerate() {
            let mut ev = 0i16;
            if c.wants_read() {
                ev |= POLLIN;
            }
            if c.wants_write() {
                ev |= POLLOUT;
            }
            busy |= c.awaiting_service();
            if ev != 0 {
                fds.push(PollFd::new(stream_fd(&c.stream), ev));
                fd_conn.push(i);
            }
        }
        let timeout = if busy { BUSY_POLL_MS } else { IDLE_POLL_MS };
        let _ = poll_fds(&mut fds, timeout);

        // 2. Accept a bounded burst.  All threads poll the listener;
        // accept() races are resolved by the kernel (losers see
        // WouldBlock).  New connections get an immediate read attempt
        // below via their recorded index.
        let first_new = conns.len();
        if !shutting {
            accept_burst(listener, state, &mut conns);
        }

        // 3. I/O on ready connections (and fresh accepts).
        let mut dead = vec![false; conns.len()];
        for (k, f) in fds.iter().enumerate() {
            let i = fd_conn[k];
            if i == usize::MAX {
                continue;
            }
            if f.writable()
                && conns[i].wants_write()
                && !flush_conn(&mut conns[i], state)
            {
                dead[i] = true;
                continue;
            }
            if f.readable()
                && conns[i].wants_read()
                && !read_conn(&mut conns[i], state)
            {
                dead[i] = true;
            }
        }
        for i in first_new..conns.len() {
            if !dead[i]
                && conns[i].wants_read()
                && !read_conn(&mut conns[i], state)
            {
                dead[i] = true;
            }
        }

        // 4. Service sweep: embed replies, parked admissions, and —
        // once a response has drained — any next request the reader
        // already buffered (HTTP pipelining).  Poll can't signal the
        // latter (those bytes arrived with an earlier read), so the
        // loop sweeps for it.
        for (i, c) in conns.iter_mut().enumerate() {
            if dead[i] {
                continue;
            }
            if c.awaiting_service() && !service_sweep(c, state) {
                dead[i] = true;
                continue;
            }
            if !advance_buffered(c, state) {
                dead[i] = true;
            }
        }

        // 5. Reap sweep.
        let keep_alive = Duration::from_millis(
            state.cfg.keep_alive_ms.max(1),
        );
        let now = Instant::now();
        for (i, c) in conns.iter_mut().enumerate() {
            if dead[i] {
                continue;
            }
            // Finished drain window after an error/close response.
            if c.drain_until.is_some_and(|t| now >= t) {
                dead[i] = true;
                continue;
            }
            // Clean close: nothing buffered, peer gone or close
            // requested with the response fully written.
            if !c.wants_write() {
                if c.close_after_write && c.drain_until.is_none() {
                    dead[i] = true;
                    continue;
                }
                if c.read_closed
                    && matches!(c.phase, ConnPhase::Reading)
                {
                    dead[i] = true;
                    continue;
                }
                if shutting
                    && matches!(c.phase, ConnPhase::Reading)
                    && c.reader.buffered() == 0
                {
                    dead[i] = true;
                    continue;
                }
            }
            // Idle / stalled reap: applies to idle keep-alives, a
            // slow-loris mid-request drip, and a stalled response
            // write alike; connections waiting on the service are
            // exempt (that wait is the server's own, and bounded by
            // the batcher deadline).
            if !c.awaiting_service()
                && now.duration_since(c.last_progress) > keep_alive
            {
                state.obs.emit(
                    Event::new("http.conn.reaped")
                        .with("conn", c.conn_id)
                        .with(
                            "idle_ms",
                            now.duration_since(c.last_progress)
                                .as_millis()
                                as u64,
                        ),
                );
                dead[i] = true;
            }
        }

        // 6. Remove the dead (each drop decrements `conns_open`).
        if dead.iter().any(|&d| d) {
            let mut kept = Vec::with_capacity(conns.len());
            for (i, c) in conns.drain(..).enumerate() {
                if !dead[i] {
                    kept.push(c);
                }
            }
            conns = kept;
        }

        if shutting {
            let grace_over = shutdown_since
                .map(|t| t.elapsed() >= SHUTDOWN_GRACE)
                .unwrap_or(true);
            if conns.is_empty() || grace_over {
                return;
            }
        }
    }
}

/// Accept up to [`ACCEPT_BURST`] pending connections; over the
/// `max_conns` cap they are admitted only to be answered 503 (and far
/// over it, dropped).
fn accept_burst(
    listener: &TcpListener,
    state: &Arc<ServerState>,
    conns: &mut Vec<Conn>,
) {
    for _ in 0..ACCEPT_BURST {
        match listener.accept() {
            Ok((stream, _peer)) => {
                state.conns_accepted.fetch_add(1, Ordering::Relaxed);
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let open = state.conns_open.load(Ordering::Relaxed);
                let cap = state.cfg.max_conns as u64;
                let conn_id =
                    state.next_conn.fetch_add(1, Ordering::Relaxed);
                if open >= cap + OVER_CAP_SLACK {
                    // Flood regime: an RST beats holding any state.
                    state
                        .conns_rejected
                        .fetch_add(1, Ordering::Relaxed);
                    state.obs.emit(
                        Event::new("http.conn.overcap")
                            .with("conn", conn_id)
                            .with("action", "drop"),
                    );
                    continue;
                }
                state.conns_open.fetch_add(1, Ordering::Relaxed);
                let mut c = Conn::new(stream, conn_id, state.clone());
                if open >= cap {
                    state
                        .conns_rejected
                        .fetch_add(1, Ordering::Relaxed);
                    state.obs.emit(
                        Event::new("http.conn.overcap")
                            .with("conn", conn_id)
                            .with("action", "503"),
                    );
                    let retry_s = ((state.cfg.retry_after_ms + 999)
                        / 1000)
                        .max(1);
                    let resp = Response::error(
                        503,
                        "connection limit reached",
                    )
                    .with_header("retry-after", &retry_s.to_string());
                    // The client may already be mid-request: discard
                    // its input so the 503 survives the close.
                    c.discard_input = true;
                    c.enqueue_response(&resp, false);
                } else {
                    state.obs.emit(
                        Event::new("http.conn.open")
                            .with("conn", conn_id),
                    );
                }
                conns.push(c);
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                return;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            // Transient accept failure (e.g. EMFILE): retry next
            // cycle instead of spinning.
            Err(_) => return,
        }
    }
}

/// Drain readable bytes.  Returns `false` when the connection is dead.
/// Stops reading as soon as one complete request is parsed — the next
/// pipelined request waits until this one's response is written, which
/// is the loop's flow control.
fn read_conn(c: &mut Conn, state: &Arc<ServerState>) -> bool {
    let mut tmp = [0u8; READ_CHUNK];
    let mut discarded = 0usize;
    loop {
        match c.stream.read(&mut tmp) {
            Ok(0) => {
                c.read_closed = true;
                state.obs.emit(
                    Event::new("http.conn.eof")
                        .with("conn", c.conn_id),
                );
                // A half-closed peer may still be reading its
                // response; the reap sweep drops the connection once
                // nothing is in flight.
                return true;
            }
            Ok(n) => {
                if c.discard_input {
                    // Bounded drain: a peer streaming garbage at full
                    // rate yields the thread back to the loop after a
                    // few chunks instead of pinning it here.
                    discarded += n;
                    if discarded >= 8 * READ_CHUNK {
                        return true;
                    }
                    continue;
                }
                c.reader.push_bytes(&tmp[..n]);
                let t0 = Instant::now();
                match c.reader.try_next(state.cfg.max_body_bytes) {
                    Ok(Some(req)) => {
                        record_parse(state, t0);
                        handle_request(c, state, &req);
                        return true;
                    }
                    Ok(None) => {} // need more bytes
                    Err(HttpError::Bad { status, msg }) => {
                        protocol_error(c, state, status, &msg);
                        return true;
                    }
                    // try_next never produces Closed/Io, but the
                    // conservative response to either is a close.
                    Err(_) => return false,
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                return true;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(_) => return false,
        }
    }
}

/// Record the cost of a successful parse into the `parse_us` stage
/// histogram (no-op when metrics are disabled).
fn record_parse(state: &Arc<ServerState>, t0: Instant) {
    if state.obs.metrics_enabled() {
        state
            .obs
            .hub
            .parse_us
            .record(t0.elapsed().as_secs_f64() * 1e6);
    }
}

/// Route one parsed request and transition the connection.
fn handle_request(
    c: &mut Conn,
    state: &Arc<ServerState>,
    req: &http::Request,
) {
    c.last_progress = Instant::now();
    let keep = req.keep_alive()
        && !state.shutdown.load(Ordering::SeqCst);
    let trace_id = state.obs.next_trace_id();
    match routes::dispatch(state, req, trace_id) {
        Handled::Done(resp) => {
            c.enqueue_response(&resp, keep);
            let _ = flush_conn(c, state);
        }
        Handled::Pending(p) => {
            c.phase = ConnPhase::AwaitingReply(p, keep);
        }
        Handled::Blocked(b) => {
            c.phase = ConnPhase::AwaitingAdmission(b, keep);
        }
    }
}

/// Parse a request the reader buffered behind an earlier one (HTTP
/// pipelining) once the connection is back in `Reading` with its write
/// buffer drained.  Returns `false` when the connection is dead.
fn advance_buffered(c: &mut Conn, state: &Arc<ServerState>) -> bool {
    if c.discard_input
        || c.close_after_write
        || c.wants_write()
        || !matches!(c.phase, ConnPhase::Reading)
        || c.reader.buffered() == 0
    {
        return true;
    }
    let t0 = Instant::now();
    match c.reader.try_next(state.cfg.max_body_bytes) {
        Ok(Some(req)) => {
            record_parse(state, t0);
            handle_request(c, state, &req);
            true
        }
        Ok(None) => true, // incomplete; wait for more bytes
        Err(HttpError::Bad { status, msg }) => {
            protocol_error(c, state, status, &msg);
            true
        }
        Err(_) => false,
    }
}

/// Queue a final error response and switch to drain-then-close: the
/// byte stream can no longer be trusted to be framed.
fn protocol_error(
    c: &mut Conn,
    state: &Arc<ServerState>,
    status: u16,
    msg: &str,
) {
    let resp = Response::error(status, msg);
    c.discard_input = true;
    c.enqueue_response(&resp, false);
    let _ = flush_conn(c, state);
}

/// Advance a connection waiting on the coordinator.  Returns `false`
/// when the connection is dead.
fn service_sweep(c: &mut Conn, state: &Arc<ServerState>) -> bool {
    match std::mem::replace(&mut c.phase, ConnPhase::Reading) {
        ConnPhase::AwaitingReply(p, keep) => {
            match routes::poll_pending(state, &p) {
                Some(resp) => {
                    c.last_progress = Instant::now();
                    c.enqueue_response(&resp, keep);
                    flush_conn(c, state)
                }
                None => {
                    c.phase = ConnPhase::AwaitingReply(p, keep);
                    true
                }
            }
        }
        ConnPhase::AwaitingAdmission(b, keep) => {
            match routes::retry_blocked(state, b) {
                Handled::Done(resp) => {
                    c.last_progress = Instant::now();
                    c.enqueue_response(&resp, keep);
                    flush_conn(c, state)
                }
                Handled::Pending(p) => {
                    c.phase = ConnPhase::AwaitingReply(p, keep);
                    true
                }
                Handled::Blocked(b) => {
                    c.phase = ConnPhase::AwaitingAdmission(b, keep);
                    true
                }
            }
        }
        ConnPhase::Reading => true,
    }
}

/// Write as much buffered response as the socket accepts.  Returns
/// `false` when the connection is dead.  On full drain of a closing
/// connection: clean closes die immediately; `discard_input` closes
/// (protocol errors, over-cap 503s) half-close and linger briefly so
/// unread request bytes can't RST the response away.  Full drain also
/// closes out the `write_us` stage clock stamped at enqueue time.
fn flush_conn(c: &mut Conn, state: &Arc<ServerState>) -> bool {
    while c.write_at < c.write_buf.len() {
        match c.stream.write(&c.write_buf[c.write_at..]) {
            Ok(0) => return false,
            Ok(n) => {
                c.write_at += n;
                c.last_progress = Instant::now();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                return true;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(_) => return false,
        }
    }
    if !c.write_buf.is_empty() {
        c.write_buf = Vec::new();
        c.write_at = 0;
        if let Some(t0) = c.resp_t0.take() {
            if state.obs.metrics_enabled() {
                state
                    .obs
                    .hub
                    .write_us
                    .record(t0.elapsed().as_secs_f64() * 1e6);
            }
        }
    }
    if c.close_after_write && c.discard_input && c.drain_until.is_none()
    {
        let _ = c.stream.shutdown(std::net::Shutdown::Write);
        c.drain_until = Some(Instant::now() + CLOSE_DRAIN);
    }
    true
}
