//! Per-route HTTP metrics: hit/error counters and latency histograms,
//! surfaced by `GET /stats` next to the coordinator's
//! [`crate::coordinator::ServiceStatsSnapshot`].

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::metrics::Histogram;
use crate::ser::Json;

#[derive(Default)]
struct RouteEntry {
    hits: u64,
    errors: u64,
    latency_us: Histogram,
}

/// Mutex-guarded per-route counters.  Recording happens once per
/// request after the response is built — off the embed hot path, which
/// is dominated by the batch execution anyway.
#[derive(Default)]
pub struct RouteStats {
    inner: Mutex<BTreeMap<&'static str, RouteEntry>>,
}

impl RouteStats {
    pub fn new() -> RouteStats {
        RouteStats::default()
    }

    /// Record one handled request under a static route label.
    pub fn record(
        &self,
        route: &'static str,
        latency_us: f64,
        error: bool,
    ) {
        let mut guard = self.inner.lock().unwrap();
        let entry = guard.entry(route).or_default();
        entry.hits += 1;
        if error {
            entry.errors += 1;
        }
        entry.latency_us.record(latency_us);
    }

    /// Hit count for a route label (testing / introspection).
    pub fn hits(&self, route: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .get(route)
            .map(|e| e.hits)
            .unwrap_or(0)
    }

    /// Snapshot as a JSON object keyed by route label.
    pub fn to_json(&self) -> Json {
        let mut guard = self.inner.lock().unwrap();
        let mut obj = Json::obj();
        for (route, e) in guard.iter_mut() {
            obj = obj.with(
                route,
                Json::obj()
                    .with("hits", Json::Num(e.hits as f64))
                    .with("errors", Json::Num(e.errors as f64))
                    .with(
                        "latency_mean_us",
                        Json::Num(e.latency_us.mean()),
                    )
                    .with(
                        "latency_p50_us",
                        Json::Num(e.latency_us.percentile(50.0)),
                    )
                    .with(
                        "latency_p95_us",
                        Json::Num(e.latency_us.percentile(95.0)),
                    )
                    .with(
                        "latency_p99_us",
                        Json::Num(e.latency_us.p99()),
                    ),
            );
        }
        obj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_serializes_per_route() {
        let stats = RouteStats::new();
        for i in 0..10 {
            stats.record("POST /embed", 100.0 + i as f64, false);
        }
        stats.record("GET /stats", 5.0, false);
        stats.record("other", 1.0, true);
        assert_eq!(stats.hits("POST /embed"), 10);
        assert_eq!(stats.hits("GET /stats"), 1);
        assert_eq!(stats.hits("GET /missing"), 0);
        let v = stats.to_json();
        let embed = v.get("POST /embed").unwrap();
        assert_eq!(embed.req_f64("hits").unwrap(), 10.0);
        assert_eq!(embed.req_f64("errors").unwrap(), 0.0);
        assert!(embed.req_f64("latency_p99_us").unwrap() >= 100.0);
        let other = v.get("other").unwrap();
        assert_eq!(other.req_f64("errors").unwrap(), 1.0);
    }
}
