//! Per-route HTTP metrics: hit/error counters and latency histograms,
//! surfaced by `GET /stats` and `GET /metrics` next to the
//! coordinator's [`crate::coordinator::ServiceStatsSnapshot`].
//!
//! Routes are pre-registered in [`ROUTES`], so the hit/error path is a
//! pair of relaxed atomic adds with no lock and no map lookup; only
//! the latency histogram takes a (per-route) mutex.  Snapshots clone
//! the histogram under that short lock and do all percentile work on
//! the clone — recording never waits on a `/stats` render.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::metrics::Histogram;
use crate::ser::Json;

/// Every route label the server records, fixed at compile time.  The
/// last entry is the catch-all for 404/405 traffic.
pub const ROUTES: &[&str] = &[
    "POST /embed",
    "GET /healthz",
    "GET /stats",
    "GET /metrics",
    "GET /models",
    "POST /models/swap",
    "other",
];

#[derive(Default)]
struct RouteEntry {
    hits: AtomicU64,
    errors: AtomicU64,
    latency_us: Mutex<Histogram>,
}

/// Pre-registered per-route counters: atomic hits/errors, a mutex only
/// around each route's latency histogram.
pub struct RouteStats {
    entries: Vec<RouteEntry>,
}

impl Default for RouteStats {
    fn default() -> RouteStats {
        RouteStats::new()
    }
}

impl RouteStats {
    pub fn new() -> RouteStats {
        RouteStats {
            entries: ROUTES
                .iter()
                .map(|_| RouteEntry::default())
                .collect(),
        }
    }

    /// Index of a route label; unknown labels fold into "other".
    fn idx(route: &str) -> usize {
        ROUTES
            .iter()
            .position(|r| *r == route)
            .unwrap_or(ROUTES.len() - 1)
    }

    /// Record one handled request under a static route label.
    pub fn record(
        &self,
        route: &'static str,
        latency_us: f64,
        error: bool,
    ) {
        let e = &self.entries[Self::idx(route)];
        e.hits.fetch_add(1, Ordering::Relaxed);
        if error {
            e.errors.fetch_add(1, Ordering::Relaxed);
        }
        crate::sync::lock(&e.latency_us).record(latency_us);
    }

    /// Hit count for a route label (lock-free).
    pub fn hits(&self, route: &str) -> u64 {
        self.entries[Self::idx(route)].hits.load(Ordering::Relaxed)
    }

    /// Error count for a route label (lock-free).
    pub fn errors(&self, route: &str) -> u64 {
        self.entries[Self::idx(route)].errors.load(Ordering::Relaxed)
    }

    /// Snapshot as a JSON object keyed by route label; routes that
    /// never recorded a hit are omitted.  Percentiles are computed on a
    /// clone, so the per-route lock is held only for the copy.
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        for (route, e) in ROUTES.iter().zip(&self.entries) {
            let hits = e.hits.load(Ordering::Relaxed);
            if hits == 0 {
                continue;
            }
            let lat = crate::sync::lock(&e.latency_us).clone();
            obj = obj.with(
                route,
                Json::obj()
                    .with("hits", Json::Num(hits as f64))
                    .with(
                        "errors",
                        Json::Num(
                            e.errors.load(Ordering::Relaxed) as f64
                        ),
                    )
                    .with("latency_mean_us", Json::Num(lat.mean()))
                    .with(
                        "latency_p50_us",
                        Json::Num(lat.percentile(50.0)),
                    )
                    .with(
                        "latency_p95_us",
                        Json::Num(lat.percentile(95.0)),
                    )
                    .with("latency_p99_us", Json::Num(lat.p99())),
            );
        }
        obj
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_serializes_per_route() {
        let stats = RouteStats::new();
        for i in 0..10 {
            stats.record("POST /embed", 100.0 + i as f64, false);
        }
        stats.record("GET /stats", 5.0, false);
        stats.record("other", 1.0, true);
        assert_eq!(stats.hits("POST /embed"), 10);
        assert_eq!(stats.hits("GET /stats"), 1);
        // Unknown labels read the catch-all slot.
        assert_eq!(stats.hits("GET /missing"), stats.hits("other"));
        assert_eq!(stats.errors("other"), 1);
        let v = stats.to_json();
        let embed = v.get("POST /embed").unwrap();
        assert_eq!(embed.req_f64("hits").unwrap(), 10.0);
        assert_eq!(embed.req_f64("errors").unwrap(), 0.0);
        assert!(embed.req_f64("latency_p99_us").unwrap() >= 100.0);
        let other = v.get("other").unwrap();
        assert_eq!(other.req_f64("errors").unwrap(), 1.0);
        // Untouched routes are omitted from the snapshot.
        assert!(v.get("GET /healthz").is_none());
    }

    #[test]
    fn unknown_labels_fold_into_other_and_counts_are_atomic() {
        let stats = std::sync::Arc::new(RouteStats::new());
        let mut joins = Vec::new();
        for _ in 0..4 {
            let stats = stats.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..250 {
                    stats.record(
                        "POST /embed",
                        i as f64,
                        i % 10 == 0,
                    );
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(stats.hits("POST /embed"), 1000);
        assert_eq!(stats.errors("POST /embed"), 100);
        // hits("GET /missing") reads the catch-all slot.
        assert_eq!(stats.hits("GET /missing"), stats.hits("other"));
    }
}
