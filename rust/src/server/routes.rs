//! Route table and handlers: the operational surface of the serving
//! subsystem.
//!
//! | route              | purpose                                        |
//! |--------------------|------------------------------------------------|
//! | `POST /embed`      | rows in, embeddings out (batched, admission-controlled) |
//! | `GET /stats`       | service snapshot + per-route latency histograms |
//! | `GET /metrics`     | Prometheus text exposition (format 0.0.4)      |
//! | `GET /healthz`     | liveness                                       |
//! | `GET /models`      | registry listing (names, versions, shapes)     |
//! | `POST /models/swap`| publish a model into the registry (hot swap)   |
//!
//! Error mapping: invalid JSON / shapes → 400, gated path-swap → 403,
//! unknown route → 404, wrong method → 405, swap dim conflict → 409,
//! queue saturation → 429 + `Retry-After`, backend failure → 500.
//!
//! Every completed request also leaves an `http.request` event
//! (trace id, route, status, latency) in the observability ring.

use std::path::Path;
use std::sync::mpsc;
use std::time::Instant;

use super::http::{Request, Response};
use super::stats::ROUTES;
use super::ServerState;
use crate::config::QueuePolicy;
use crate::error::Error;
use crate::kpca::EmbeddingModel;
use crate::linalg::Matrix;
use crate::metrics::StageSnapshot;
use crate::obs::prom::{self, PromText};
use crate::obs::Event;
use crate::ser::Json;

/// An embed request that has been admitted to the coordinator queue;
/// the event loop holds this and polls [`poll_pending`] until the
/// reply arrives.  Route stats are recorded at completion, so the
/// latency covers queue wait + batch execution, exactly like the old
/// blocking dispatch did.
pub(super) struct PendingEmbed {
    rx: mpsc::Receiver<crate::error::Result<Matrix>>,
    version_before: u64,
    t_start: Instant,
    /// Trace id minted at accept time; ties the `http.request` event
    /// to the coordinator's `span.embed` for the same request.
    trace_id: u64,
}

/// Resolve a request's absolute deadline on the service clock:
/// explicit `X-Deadline-Ms` header first (an unparsable value is
/// treated as absent; an explicit `0` is a valid, already-expired
/// budget), then the `[server] default_deadline_ms` fallback; `0`
/// means no deadline.
fn resolve_deadline(state: &ServerState, req: &Request) -> u64 {
    match req
        .header("x-deadline-ms")
        .and_then(|v| v.trim().parse::<u64>().ok())
    {
        Some(ms) => state
            .handle
            .now_us()
            .saturating_add(ms.saturating_mul(1000)),
        None if state.cfg.default_deadline_ms > 0 => {
            state.handle.now_us().saturating_add(
                state.cfg.default_deadline_ms.saturating_mul(1000),
            )
        }
        None => 0,
    }
}

/// An embed request refused by a saturated queue under
/// `queue_policy = "block"`: the connection parks (no thread blocks)
/// and the event loop re-attempts admission each cycle via
/// [`retry_blocked`].
pub(super) struct BlockedEmbed {
    rows: Matrix,
    version_before: u64,
    t_start: Instant,
    trace_id: u64,
    /// Absolute end-to-end deadline (service clock, µs); `0` = none.
    /// Checked on every re-admission attempt so a parked request can't
    /// outlive its budget waiting for queue space.
    deadline_us: u64,
}

/// The three ways a request leaves the router.
pub(super) enum Handled {
    /// Response is ready now (every non-embed route, and embed-level
    /// errors such as bad JSON or immediate 429s).
    Done(Response),
    /// Embed admitted; await the reply receiver.
    Pending(PendingEmbed),
    /// Embed parked on a saturated queue (block policy).
    Blocked(BlockedEmbed),
}

/// Dispatch one request.  Non-embed routes are synchronous and cheap
/// (registry/stat reads), so they complete inline — only `POST /embed`
/// can return `Pending`/`Blocked`.
pub(super) fn dispatch(
    state: &ServerState,
    req: &Request,
    trace_id: u64,
) -> Handled {
    let t = Instant::now();
    if req.method == "POST" && req.path() == "/embed" {
        return embed_submit(state, req, t, trace_id);
    }
    let (label, resp) = route(state, req);
    let us = t.elapsed().as_secs_f64() * 1e6;
    state.routes.record(label, us, resp.status >= 400);
    emit_request(state, trace_id, label, resp.status, us);
    Handled::Done(resp)
}

fn route(
    state: &ServerState,
    req: &Request,
) -> (&'static str, Response) {
    match (req.method.as_str(), req.path()) {
        ("GET", "/healthz") => ("GET /healthz", healthz(state)),
        ("GET", "/stats") => ("GET /stats", stats(state)),
        ("GET", "/metrics") => ("GET /metrics", metrics(state)),
        ("GET", "/models") => ("GET /models", models(state)),
        ("POST", "/models/swap") => {
            ("POST /models/swap", swap(state, req))
        }
        (_, "/healthz" | "/stats" | "/metrics" | "/models"
            | "/models/swap" | "/embed") => (
            "other",
            Response::error(405, "method not allowed for this route"),
        ),
        _ => ("other", Response::error(404, "no such route")),
    }
}

/// Leave the per-request `http.request` event in the ring: the span
/// root every stage event shares a trace id with.
fn emit_request(
    state: &ServerState,
    trace_id: u64,
    route: &'static str,
    status: u16,
    us: f64,
) {
    state.obs.emit(
        Event::new("http.request")
            .trace(trace_id)
            .with("route", route)
            .with("status", u64::from(status))
            .with("us", us),
    );
}

fn healthz(state: &ServerState) -> Response {
    // Liveness stays 200 even when degraded: the server is up and
    // serving its last good model; "degraded" flags that the
    // background refresher's circuit breaker is open (or probing).
    let breaker = state.obs.hub.breaker_state();
    let status = if breaker == 0 { "ok" } else { "degraded" };
    let breaker_name = match breaker {
        0 => "closed",
        1 => "open",
        _ => "half-open",
    };
    Response::json(
        200,
        &Json::obj()
            .with("status", Json::Str(status.into()))
            .with(
                "model",
                Json::Str(state.handle.model_name().to_string()),
            )
            .with("refresh_breaker", Json::Str(breaker_name.into()))
            .with(
                "uptime_s",
                Json::Num(state.started.elapsed().as_secs_f64()),
            ),
    )
}

fn stats(state: &ServerState) -> Response {
    let s = state.handle.stats();
    let service = Json::obj()
        .with("requests", Json::Num(s.requests as f64))
        .with("rejected", Json::Num(s.rejected as f64))
        .with("rows", Json::Num(s.rows as f64))
        .with("batches", Json::Num(s.batches as f64))
        .with("latency_p50_us", Json::Num(s.latency_p50_us))
        .with("latency_p95_us", Json::Num(s.latency_p95_us))
        .with("latency_p99_us", Json::Num(s.latency_p99_us))
        .with("mean_batch_rows", Json::Num(s.mean_batch_rows))
        .with("max_batch_rows", Json::Num(s.max_batch_rows))
        .with("model_swaps", Json::Num(s.model_swaps as f64))
        .with("model_version", Json::Num(s.model_version as f64))
        .with(
            "model_precision",
            Json::Str(s.model_precision.name().into()),
        );
    let service = match s.model_quant {
        // Publish-time quantization diagnostic: f64-vs-f32 relative
        // embedding error measured on the probe block.
        Some(q) => service
            .with("quant_max_rel", Json::Num(q.max_rel))
            .with("quant_mean_rel", Json::Num(q.mean_rel)),
        None => service,
    };
    let http = Json::obj()
        .with(
            "conns_accepted",
            Json::Num(state.conns_accepted() as f64),
        )
        .with(
            "conns_rejected",
            Json::Num(state.conns_rejected() as f64),
        )
        .with("conns_open", Json::Num(state.conns_open() as f64));
    let hub = &state.obs.hub;
    let mut stages = Json::obj();
    for (name, snap) in [
        ("parse_us", hub.parse_us.snapshot()),
        ("queue_wait_us", hub.queue_wait_us.snapshot()),
        ("assembly_us", hub.assembly_us.snapshot()),
        ("embed_us", hub.embed_us.snapshot()),
        ("gemm_us", hub.gemm_us.snapshot()),
        ("profile_us", hub.profile_us.snapshot()),
        ("coeff_us", hub.coeff_us.snapshot()),
        ("write_us", hub.write_us.snapshot()),
    ] {
        stages = stages.with(name, stage_json(&snap));
    }
    let occupancy = hub.batch_rows.snapshot();
    // Which GEMM kernel production is actually running, plus the
    // persistent work-pool state (the two hardware levers this crate
    // pulls) — so a scrape answers "is this host on the SIMD path and
    // are the workers parked or busy" without a debugger.
    let pool = crate::parallel::pool_stats();
    let compute = Json::obj()
        .with(
            "simd_kernel",
            Json::Str(crate::linalg::simd::active_name().into()),
        )
        .with("pool_threads", Json::Num(pool.threads as f64))
        .with("pool_workers", Json::Num(pool.workers as f64))
        .with("pool_busy", Json::Num(pool.busy as f64))
        .with("pool_jobs", Json::Num(pool.jobs as f64))
        .with("pool_wakes", Json::Num(pool.wakes as f64))
        .with("pool_parks", Json::Num(pool.parks as f64))
        .with(
            "pool_spawn_fallbacks",
            Json::Num(pool.spawn_fallbacks as f64),
        );
    let obs = Json::obj()
        .with(
            "events_dropped",
            Json::Num(state.obs.events_dropped() as f64),
        )
        .with(
            "requests_1m",
            Json::Num(
                hub.requests_1m.sum(state.obs.now_s()) as f64,
            ),
        );
    Response::json(
        200,
        &Json::obj()
            .with("service", service)
            .with("routes", state.routes.to_json())
            .with("http", http)
            .with("compute", compute)
            .with("stages", stages)
            .with(
                "batch_occupancy",
                Json::obj()
                    .with("batches", Json::Num(occupancy.count as f64))
                    .with("mean_rows", Json::Num(occupancy.mean()))
                    .with(
                        "p99_rows",
                        Json::Num(occupancy.quantile(99.0)),
                    ),
            )
            .with("obs", obs)
            .with(
                "uptime_s",
                Json::Num(state.started.elapsed().as_secs_f64()),
            ),
    )
}

/// Compact JSON summary of one stage histogram snapshot.
fn stage_json(snap: &StageSnapshot) -> Json {
    Json::obj()
        .with("count", Json::Num(snap.count as f64))
        .with("mean", Json::Num(snap.mean()))
        .with("p50", Json::Num(snap.quantile(50.0)))
        .with("p99", Json::Num(snap.quantile(99.0)))
}

/// Render the full Prometheus exposition document.  Counters come from
/// the coordinator snapshot and the server's atomics; histograms from
/// the lock-free stage hub — the handler only reads, so a scrape never
/// blocks the request path.
fn metrics(state: &ServerState) -> Response {
    if !state.obs.metrics_enabled() {
        return Response::error(
            404,
            "metrics disabled ([obs] metrics = false)",
        );
    }
    let s = state.handle.stats();
    let hub = &state.obs.hub;
    let mut p = PromText::new();
    p.counter(
        "rskpca_requests_total",
        "Embed requests completed by the batch worker.",
        s.requests as f64,
    );
    p.counter(
        "rskpca_rejected_total",
        "Embed requests rejected by queue admission control.",
        s.rejected as f64,
    );
    p.counter(
        "rskpca_rows_total",
        "Embedding rows computed.",
        s.rows as f64,
    );
    p.counter(
        "rskpca_batches_total",
        "Batches flushed by the size-OR-deadline batcher.",
        s.batches as f64,
    );
    p.counter(
        "rskpca_model_swaps_total",
        "Model hot swaps observed by the batch worker.",
        s.model_swaps as f64,
    );
    p.gauge(
        "rskpca_model_version",
        "Version of the currently served model.",
        s.model_version as f64,
    );
    p.counter(
        "rskpca_http_conns_accepted_total",
        "TCP connections accepted.",
        state.conns_accepted() as f64,
    );
    p.counter(
        "rskpca_http_conns_rejected_total",
        "Connections refused over the max_conns cap.",
        state.conns_rejected() as f64,
    );
    p.gauge(
        "rskpca_http_conns_open",
        "Currently open connections.",
        state.conns_open() as f64,
    );
    p.gauge(
        "rskpca_requests_1m",
        "Embed requests completed over the trailing minute.",
        hub.requests_1m.sum(state.obs.now_s()) as f64,
    );
    p.gauge(
        "rskpca_uptime_seconds",
        "Seconds since the server started.",
        state.started.elapsed().as_secs_f64(),
    );
    p.counter(
        "rskpca_obs_events_dropped_total",
        "Observability events dropped by the bounded ring.",
        state.obs.events_dropped() as f64,
    );
    p.counter(
        "rskpca_worker_panics_total",
        "Panics caught by supervised workers (batch worker, event \
         loops, refresher).",
        hub.worker_panics() as f64,
    );
    p.counter(
        "rskpca_worker_restarts_total",
        "Supervised restarts: thread restarts and post-panic backend \
         rebuilds.",
        hub.worker_restarts() as f64,
    );
    p.counter(
        "rskpca_deadline_shed_total",
        "Embed requests shed because their end-to-end deadline \
         expired before compute.",
        hub.deadline_shed() as f64,
    );
    p.counter(
        "rskpca_model_corrupt_total",
        "Model files quarantined after checksum verification failed.",
        hub.model_corrupt() as f64,
    );
    p.gauge(
        "rskpca_refresh_breaker_state",
        "Background-refresher circuit breaker (0=closed, 1=open, \
         2=half-open).",
        hub.breaker_state() as f64,
    );
    // Compute-engine state: the active GEMM ISA as a one-hot labeled
    // gauge (the Prometheus idiom for "which variant"), and the
    // persistent work-pool counters.
    let pool = crate::parallel::pool_stats();
    p.gauge_vec(
        "rskpca_simd_kernel",
        "Active GEMM micro-kernel ISA (1 on the selected label).",
        "kernel",
        &[(crate::linalg::simd::active_name(), 1.0)],
    );
    p.gauge(
        "rskpca_pool_threads",
        "Compute threads the parallel engine fans out to (workers + \
         the submitting caller).",
        pool.threads as f64,
    );
    p.gauge(
        "rskpca_pool_busy",
        "Pool parts executing right now.",
        pool.busy as f64,
    );
    p.counter(
        "rskpca_pool_jobs_total",
        "Parallel jobs dispatched through the persistent pool.",
        pool.jobs as f64,
    );
    p.counter(
        "rskpca_pool_wakes_total",
        "Worker wakeups from the parked state.",
        pool.wakes as f64,
    );
    p.counter(
        "rskpca_pool_parks_total",
        "Worker transitions into the parked (idle) state.",
        pool.parks as f64,
    );
    p.counter(
        "rskpca_pool_spawn_fallback_total",
        "Dispatches that fell back to per-call spawned threads \
         (nested parallelism or a draining pool).",
        pool.spawn_fallbacks as f64,
    );
    let hits: Vec<(&str, f64)> = ROUTES
        .iter()
        .map(|r| (*r, state.routes.hits(r) as f64))
        .collect();
    p.counter_vec(
        "rskpca_route_hits_total",
        "HTTP requests handled, per route.",
        "route",
        &hits,
    );
    let errors: Vec<(&str, f64)> = ROUTES
        .iter()
        .map(|r| (*r, state.routes.errors(r) as f64))
        .collect();
    p.counter_vec(
        "rskpca_route_errors_total",
        "HTTP error responses (status >= 400), per route.",
        "route",
        &errors,
    );
    p.histogram(
        "rskpca_parse_us",
        "HTTP request parse time (us).",
        &hub.parse_us.snapshot(),
    );
    p.histogram(
        "rskpca_queue_wait_us",
        "Queue wait: enqueue to worker pickup (us).",
        &hub.queue_wait_us.snapshot(),
    );
    p.histogram(
        "rskpca_assembly_us",
        "Batch assembly wait: pickup to execution (us).",
        &hub.assembly_us.snapshot(),
    );
    p.histogram(
        "rskpca_embed_us",
        "Backend embed call per batch (us).",
        &hub.embed_us.snapshot(),
    );
    p.histogram(
        "rskpca_gemm_us",
        "Gram GEMM inside the embed (us).",
        &hub.gemm_us.snapshot(),
    );
    p.histogram(
        "rskpca_profile_us",
        "Kernel profile epilogue inside the embed (us).",
        &hub.profile_us.snapshot(),
    );
    p.histogram(
        "rskpca_coeff_us",
        "Coefficient fold inside the embed (us).",
        &hub.coeff_us.snapshot(),
    );
    p.histogram(
        "rskpca_write_us",
        "Response write: enqueue to socket drain (us).",
        &hub.write_us.snapshot(),
    );
    p.histogram(
        "rskpca_batch_rows",
        "Batch occupancy: rows per flushed batch.",
        &hub.batch_rows.snapshot(),
    );
    Response {
        status: 200,
        content_type: prom::CONTENT_TYPE,
        body: p.finish().into_bytes(),
        extra_headers: Vec::new(),
    }
}

fn models(state: &ServerState) -> Response {
    let registry = state.handle.registry();
    let serving = state.handle.model_name().to_string();
    let mut entries = Vec::new();
    for name in registry.names() {
        if let Some((model, version)) = registry.get_versioned(&name) {
            let mut entry = Json::obj()
                .with("name", Json::Str(name.clone()))
                .with("version", Json::Num(version as f64))
                .with(
                    "method",
                    Json::Str(model.method.clone()),
                )
                .with(
                    "centers",
                    Json::Num(model.n_retained() as f64),
                )
                .with("rank", Json::Num(model.r() as f64))
                .with(
                    "dim",
                    Json::Num(model.centers.cols() as f64),
                )
                .with("serving", Json::Bool(name == serving))
                .with(
                    "precision",
                    Json::Str(model.precision().name().into()),
                );
            if let Some(q) = model.quant_error() {
                entry = entry
                    .with("quant_max_rel", Json::Num(q.max_rel))
                    .with("quant_mean_rel", Json::Num(q.mean_rel));
            }
            entries.push(entry);
        }
    }
    Response::json(
        200,
        &Json::obj()
            .with("serving", Json::Str(serving))
            .with("models", Json::Arr(entries))
            .with(
                "swap_count",
                Json::Num(registry.swap_count() as f64),
            ),
    )
}

fn swap(state: &ServerState, req: &Request) -> Response {
    let v = match parse_json_body(&req.body) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let name = match v.get("name") {
        None => state.handle.model_name().to_string(),
        Some(Json::Str(s)) => s.clone(),
        Some(_) => {
            return Response::error(400, "'name' must be a string")
        }
    };
    let model = if let Some(mj) = v.get("model") {
        match EmbeddingModel::from_json(mj) {
            Ok(m) => m,
            Err(e) => {
                return Response::error(
                    400,
                    &format!("bad inline model: {e}"),
                )
            }
        }
    } else if let Some(p) = v.get("path").and_then(|p| p.as_str()) {
        // Server-side file loads are an operator opt-in: the route is
        // unauthenticated, so by default clients may only ship the
        // model inline.
        if !state.cfg.allow_path_swap {
            return Response::error(
                403,
                "path-based swap is disabled; send the model inline \
                 or set [server] allow_path_swap = true",
            );
        }
        // Checked load: verifies the v4 checksum trailer and
        // quarantines (renames to `.corrupt`) a file that fails it,
        // emitting a `model.corrupt` event into the shared ring.
        match EmbeddingModel::load_checked(
            Path::new(p),
            Some(&state.obs),
        ) {
            Ok(m) => m,
            Err(e) => {
                return Response::error(
                    400,
                    &format!("cannot load model from '{p}': {e}"),
                )
            }
        }
    } else {
        return Response::error(
            400,
            "swap needs an inline 'model' or a server-side 'path'",
        );
    };
    let registry = state.handle.registry();
    // Refuse a swap that would change the feature dimension of an
    // existing slot: the service handles validated requests against
    // the old dim, and the batch executor would refuse every batch.
    if let Some(current) = registry.get(&name) {
        if current.centers.cols() != model.centers.cols() {
            return Response::error(
                409,
                &format!(
                    "slot '{name}' serves dim {}, new model has dim {}",
                    current.centers.cols(),
                    model.centers.cols()
                ),
            );
        }
    }
    let version = registry.publish(&name, model);
    Response::json(
        200,
        &Json::obj()
            .with("name", Json::Str(name))
            .with("version", Json::Num(version as f64))
            .with(
                "swap_count",
                Json::Num(registry.swap_count() as f64),
            ),
    )
}

/// Parse, tap, and submit a `POST /embed` body — without ever blocking
/// the calling (event-loop) thread.
fn embed_submit(
    state: &ServerState,
    req: &Request,
    t_start: Instant,
    trace_id: u64,
) -> Handled {
    let v = match parse_json_body(&req.body) {
        Ok(v) => v,
        Err(resp) => {
            return done_embed(state, resp, t_start, trace_id)
        }
    };
    let rows = match rows_from_json(&v) {
        Ok(m) => m,
        Err(msg) => {
            return done_embed(
                state,
                Response::error(400, &msg),
                t_start,
                trace_id,
            )
        }
    };
    // Lossy tap for the background refresher (`serve --refresh N`):
    // never blocks the request path — when the refresher is mid-refit
    // the sample is simply dropped.
    if let Some(feed) = &state.refresh_feed {
        if let Ok(tx) = feed.lock() {
            let _ = tx.try_send(rows.clone());
        }
    }
    // Registry version before submission: versions only ever
    // increment, so if it is unchanged after the reply, no swap
    // happened in between and the batch provably served this version.
    let version_before = state
        .handle
        .registry()
        .version(state.handle.model_name())
        .unwrap_or(0);
    let deadline_us = resolve_deadline(state, req);
    if state.cfg.queue_policy == QueuePolicy::Block {
        // Block policy, event-loop style: a saturated queue parks the
        // *connection*, not a thread — admission is retried each
        // cycle (and the parked attempts never count as rejections,
        // matching the old blocking-send semantics).
        match state.handle.try_embed_quiet(
            rows.clone(),
            trace_id,
            deadline_us,
        ) {
            Ok(rx) => Handled::Pending(PendingEmbed {
                rx,
                version_before,
                t_start,
                trace_id,
            }),
            Err(Error::Saturated(_)) => Handled::Blocked(BlockedEmbed {
                rows,
                version_before,
                t_start,
                trace_id,
                deadline_us,
            }),
            Err(e) => done_embed(
                state,
                embed_error(state, e),
                t_start,
                trace_id,
            ),
        }
    } else {
        match state.handle.try_embed_traced(rows, trace_id, deadline_us)
        {
            Ok(rx) => Handled::Pending(PendingEmbed {
                rx,
                version_before,
                t_start,
                trace_id,
            }),
            Err(e) => done_embed(
                state,
                embed_error(state, e),
                t_start,
                trace_id,
            ),
        }
    }
}

/// Check a pending embed for its reply; `None` means still in flight.
pub(super) fn poll_pending(
    state: &ServerState,
    p: &PendingEmbed,
) -> Option<Response> {
    match p.rx.try_recv() {
        Ok(result) => Some(finish_embed(state, result, p)),
        Err(mpsc::TryRecvError::Empty) => None,
        Err(mpsc::TryRecvError::Disconnected) => {
            let resp = Response::error(500, "service dropped reply");
            record_embed(state, &resp, p.t_start, p.trace_id);
            Some(resp)
        }
    }
}

/// Re-attempt admission for a parked (block-policy) embed.  An expired
/// deadline is checked *first*: a request that outlived its budget
/// waiting for queue space is shed here with a 504 instead of being
/// admitted to compute it can no longer use.
pub(super) fn retry_blocked(
    state: &ServerState,
    b: BlockedEmbed,
) -> Handled {
    if b.deadline_us != 0 && state.handle.now_us() >= b.deadline_us {
        state.obs.hub.record_deadline_shed();
        state.obs.emit(
            Event::new("embed.expired")
                .trace(b.trace_id)
                .with("rows", b.rows.rows())
                .with("where", "parked"),
        );
        let resp = embed_error(
            state,
            Error::DeadlineExceeded(
                "deadline expired while parked on a saturated queue"
                    .into(),
            ),
        );
        record_embed(state, &resp, b.t_start, b.trace_id);
        return Handled::Done(resp);
    }
    match state.handle.try_embed_quiet(
        b.rows.clone(),
        b.trace_id,
        b.deadline_us,
    ) {
        Ok(rx) => Handled::Pending(PendingEmbed {
            rx,
            version_before: b.version_before,
            t_start: b.t_start,
            trace_id: b.trace_id,
        }),
        Err(Error::Saturated(_)) => Handled::Blocked(b),
        Err(e) => {
            let resp = embed_error(state, e);
            record_embed(state, &resp, b.t_start, b.trace_id);
            Handled::Done(resp)
        }
    }
}

/// Build the final embed response from the service reply and record
/// the route stats.
fn finish_embed(
    state: &ServerState,
    result: crate::error::Result<Matrix>,
    p: &PendingEmbed,
) -> Response {
    let resp = match result {
        Ok(z) => {
            let version_after = state
                .handle
                .registry()
                .version(state.handle.model_name())
                .unwrap_or(0);
            // Null during a swap window: the batch ran against one of
            // the two versions and the handler cannot know which.
            let version = if p.version_before == version_after {
                Json::Num(version_after as f64)
            } else {
                Json::Null
            };
            Response::json(
                200,
                &Json::obj()
                    .with("rows", Json::Num(z.rows() as f64))
                    .with("rank", Json::Num(z.cols() as f64))
                    .with("model_version", version)
                    .with("embedding", matrix_to_json(&z)),
            )
        }
        Err(e) => embed_error(state, e),
    };
    record_embed(state, &resp, p.t_start, p.trace_id);
    resp
}

/// Map an embed-path error to its response.
fn embed_error(state: &ServerState, e: Error) -> Response {
    match e {
        Error::Saturated(m) => {
            // Admission control: saturation is transient, so answer
            // 429 with a Retry-After hint instead of queueing the
            // connection behind the embed queue.
            let retry_ms = state.cfg.retry_after_ms;
            let retry_s = ((retry_ms + 999) / 1000).max(1);
            Response::json(
                429,
                &Json::obj()
                    .with("error", Json::Str(m))
                    .with("status", Json::Num(429.0))
                    .with(
                        "retry_after_ms",
                        Json::Num(retry_ms as f64),
                    ),
            )
            .with_header("retry-after", &retry_s.to_string())
        }
        Error::DeadlineExceeded(m) => {
            // The request's end-to-end budget ran out before compute;
            // the work was shed, not attempted — 504, and retrying
            // with a larger `X-Deadline-Ms` may succeed.
            Response::json(
                504,
                &Json::obj()
                    .with("error", Json::Str(m))
                    .with("status", Json::Num(504.0)),
            )
        }
        Error::Shape(m) => Response::error(400, &m),
        e => Response::error(500, &e.to_string()),
    }
}

/// Record embed route stats at completion time and pass the response
/// through (used for the immediate-error paths).
fn done_embed(
    state: &ServerState,
    resp: Response,
    t_start: Instant,
    trace_id: u64,
) -> Handled {
    record_embed(state, &resp, t_start, trace_id);
    Handled::Done(resp)
}

fn record_embed(
    state: &ServerState,
    resp: &Response,
    t_start: Instant,
    trace_id: u64,
) {
    let us = t_start.elapsed().as_secs_f64() * 1e6;
    state.routes.record("POST /embed", us, resp.status >= 400);
    emit_request(state, trace_id, "POST /embed", resp.status, us);
}

/// Parse a request body as JSON (400 on non-UTF-8 or bad JSON).
fn parse_json_body(body: &[u8]) -> Result<Json, Response> {
    let text = std::str::from_utf8(body).map_err(|_| {
        Response::error(400, "body is not valid utf-8")
    })?;
    crate::ser::parse(text).map_err(|e| {
        Response::error(400, &format!("bad json body: {e}"))
    })
}

/// Extract `{"rows": [[f64, ...], ...]}` into a row-major matrix.
fn rows_from_json(v: &Json) -> Result<Matrix, String> {
    let rows = v
        .get("rows")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| {
            "body must be {\"rows\": [[...], ...]}".to_string()
        })?;
    if rows.is_empty() {
        return Err("'rows' must not be empty".into());
    }
    let cols = rows[0]
        .as_arr()
        .map(|a| a.len())
        .ok_or_else(|| "'rows' items must be arrays".to_string())?;
    if cols == 0 {
        return Err("rows must have at least one column".into());
    }
    let mut m = Matrix::zeros(rows.len(), cols);
    for (i, row) in rows.iter().enumerate() {
        let row = row
            .as_arr()
            .ok_or_else(|| format!("row {i} is not an array"))?;
        if row.len() != cols {
            return Err(format!(
                "ragged rows: row {i} has {} columns, row 0 has {cols}",
                row.len()
            ));
        }
        for (j, x) in row.iter().enumerate() {
            m.set(
                i,
                j,
                x.as_f64().ok_or_else(|| {
                    format!("row {i} col {j} is not a number")
                })?,
            );
        }
    }
    Ok(m)
}

/// Nested-array JSON view of a matrix (row major).
fn matrix_to_json(m: &Matrix) -> Json {
    let mut rows = Vec::with_capacity(m.rows());
    for i in 0..m.rows() {
        rows.push(Json::from_f64_slice(m.row(i)));
    }
    Json::Arr(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_parse_validates_shape() {
        let ok = crate::ser::parse(r#"{"rows": [[1, 2], [3, 4]]}"#)
            .unwrap();
        let m = rows_from_json(&ok).unwrap();
        assert_eq!((m.rows(), m.cols()), (2, 2));
        assert_eq!(m.get(1, 0), 3.0);

        for bad in [
            r#"{"cols": []}"#,
            r#"{"rows": []}"#,
            r#"{"rows": [[]]}"#,
            r#"{"rows": [[1, 2], [3]]}"#,
            r#"{"rows": [[1, "x"]]}"#,
            r#"{"rows": [1, 2]}"#,
        ] {
            let v = crate::ser::parse(bad).unwrap();
            assert!(rows_from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn matrix_json_roundtrips() {
        let m = Matrix::from_vec(2, 3, vec![1.5, -2.0, 0.25, 4.0, 5.0, -6.5])
            .unwrap();
        let j = Json::obj().with("rows", matrix_to_json(&m));
        let back = rows_from_json(&j).unwrap();
        assert_eq!(back, m);
    }
}
