//! Ctrl-C / SIGTERM → an atomic shutdown flag, with no signal crate:
//! a two-declaration shim over the C runtime's `signal` entry point
//! (already linked into every Rust binary), one of the crate's four
//! sanctioned `unsafe` sites ({signal, poll, simd, pool} — see
//! ARCHITECTURE.md).  The handler body is async-signal-safe — it
//! stores to a
//! static atomic and returns; the serve loop polls
//! [`shutdown_requested`] and runs the orderly teardown (acceptor
//! close → connection drain → worker join) on the main thread.

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Has a shutdown been requested (signal received, or
/// [`request_shutdown`] called) since process start?
pub fn shutdown_requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Trip the shutdown flag programmatically (tests, non-unix fallback).
pub fn request_shutdown() {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Route SIGINT (Ctrl-C) and SIGTERM to the shutdown flag.  Safe to
/// call more than once; later installs are no-ops at the OS level.
#[cfg(unix)]
pub fn install_shutdown_handler() {
    extern "C" fn on_signal(_signum: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        // `sighandler_t signal(int, sighandler_t)`; the return value
        // (previous handler) is pointer-sized and ignored here.
        fn signal(
            signum: i32,
            handler: extern "C" fn(i32),
        ) -> *const std::ffi::c_void;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

/// Non-unix fallback: no OS hook; Ctrl-C kills the process, but
/// [`request_shutdown`] still works for in-process teardown.
#[cfg(not(unix))]
pub fn install_shutdown_handler() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_trips_once_requested() {
        // Handler installation must not blow up, and the programmatic
        // path must flip the flag (the signal path needs a process to
        // kill — covered by ci.sh's SIGTERM smoke).
        install_shutdown_handler();
        request_shutdown();
        assert!(shutdown_requested());
    }
}
