//! End-to-end integration: fit -> save -> load -> serve round-trips, the
//! experiment drivers at smoke scale, and the CLI surface.

use std::path::PathBuf;

use rskpca::classify::{accuracy, KnnClassifier};
use rskpca::config::ServiceConfig;
use rskpca::coordinator::serve;
use rskpca::data::{train_test_split};
use rskpca::density::{RsdeEstimator, ShadowDensity};
use rskpca::experiments::{self, dataset_by_name, sigma_for, ExperimentCtx};
use rskpca::kernel::Kernel;
use rskpca::kpca::{fit_kpca, fit_rskpca, EmbeddingModel};
use rskpca::runtime::NativeBackend;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rskpca_e2e_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn fit_save_load_serve_roundtrip() {
    let ds = dataset_by_name("german", 0.3, 7).unwrap();
    let (train, test) = train_test_split(&ds, 0.8, 1);
    let kernel = Kernel::gaussian(sigma_for(&ds));
    let rs = ShadowDensity::new(4.0).reduce(&train.x, &kernel);
    let model = fit_rskpca(&rs, &kernel, 5).unwrap();
    let expect = model.transform(&test.x);

    // save -> load
    let path = tmpdir("roundtrip").join("model.json");
    model.save(&path).unwrap();
    let loaded = EmbeddingModel::load(&path).unwrap();

    // serve the loaded model
    let svc = serve(
        loaded,
        Box::new(|| Ok(Box::new(NativeBackend))),
        ServiceConfig::default(),
    )
    .unwrap();
    let got = svc.handle().embed(test.x.clone()).unwrap();
    assert!(got.sub(&expect).unwrap().max_abs() < 1e-9);
    svc.shutdown();
}

#[test]
fn rskpca_embeddings_classify_comparably_to_kpca() {
    // The headline behavioural claim at small scale: RSKPCA's embedding
    // is as useful for classification as full KPCA's while retaining a
    // fraction of the data.
    let ds = dataset_by_name("pendigits", 0.2, 3).unwrap();
    let (train, test) = train_test_split(&ds, 0.85, 2);
    let kernel = Kernel::gaussian(sigma_for(&ds));
    let full = fit_kpca(&train.x, &kernel, 5).unwrap();
    let rs = ShadowDensity::new(4.0).reduce(&train.x, &kernel);
    assert!(rs.retention() < 0.9, "no compression at ell=4");
    let reduced = fit_rskpca(&rs, &kernel, 5).unwrap();

    let acc = |model: &EmbeddingModel| {
        let zt = model.transform(&train.x);
        let zs = model.transform(&test.x);
        let knn = KnnClassifier::fit(zt, train.y.clone(), 3);
        accuracy(&knn.predict(&zs), &test.y)
    };
    let acc_full = acc(&full);
    let acc_red = acc(&reduced);
    assert!(
        acc_red >= acc_full - 0.08,
        "rskpca acc {acc_red} much worse than kpca {acc_full}"
    );
}

#[test]
fn experiment_drivers_smoke_at_tiny_scale() {
    let mut ctx = ExperimentCtx::quick();
    ctx.out_dir = tmpdir("experiments");
    ctx.scale = 0.05;
    ctx.runs = 1;
    ctx.ell_step = 2.0;
    for exp in ["fig2", "fig4", "fig7", "table2"] {
        experiments::run(exp, &ctx)
            .unwrap_or_else(|e| panic!("{exp} failed: {e}"));
    }
    assert!(ctx
        .out_dir
        .join("fig2_eigenembedding_german.csv")
        .exists());
    assert!(ctx
        .out_dir
        .join("fig4_classification_usps.csv")
        .exists());
    assert!(ctx.out_dir.join("fig7_rsde_schemes_usps.csv").exists());
    assert!(ctx.out_dir.join("table2_cost.csv").exists());
    // CSVs have headers + at least one data row.
    for f in [
        "fig2_eigenembedding_german.csv",
        "fig4_classification_usps.csv",
    ] {
        let text =
            std::fs::read_to_string(ctx.out_dir.join(f)).unwrap();
        assert!(text.lines().count() >= 2, "{f} empty");
    }
}

#[test]
fn cli_fit_and_embed_commands_compose() {
    let dir = tmpdir("cli");
    let cfg_path = dir.join("run.toml");
    std::fs::write(
        &cfg_path,
        "[run]\ndataset = \"gmm2d\"\nell = 4.0\nrank = 3\n",
    )
    .unwrap();
    let model_path = dir.join("model.json");
    let data_path = dir.join("data.csv");
    let emb_path = dir.join("emb.csv");

    let run = |args: &[&str]| {
        rskpca::cli::dispatch(
            &args.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        )
    };
    run(&[
        "fit",
        "--config",
        cfg_path.to_str().unwrap(),
        "--model-out",
        model_path.to_str().unwrap(),
    ])
    .unwrap();
    run(&[
        "gen",
        "--dataset",
        "gmm2d",
        "--out",
        data_path.to_str().unwrap(),
        "--seed",
        "3",
    ])
    .unwrap();
    run(&[
        "embed",
        "--model",
        model_path.to_str().unwrap(),
        "--data",
        data_path.to_str().unwrap(),
        "--out",
        emb_path.to_str().unwrap(),
    ])
    .unwrap();
    let emb = std::fs::read_to_string(&emb_path).unwrap();
    assert_eq!(emb.lines().count(), 1000);
    // label,z0,z1,z2 per line.
    assert_eq!(emb.lines().next().unwrap().split(',').count(), 4);

    // serve command drives the loaded model end to end.
    run(&[
        "serve",
        "--model",
        model_path.to_str().unwrap(),
        "--requests",
        "20",
        "--rows-per-request",
        "4",
    ])
    .unwrap();
}

#[test]
fn cli_rejects_bad_invocations() {
    let run = |args: &[&str]| {
        rskpca::cli::dispatch(
            &args.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        )
    };
    assert!(run(&["experiment"]).is_err()); // missing name
    assert!(run(&["experiment", "fig99", "--quick"]).is_err());
    assert!(run(&["fit"]).is_err()); // missing flags
    assert!(run(&["embed", "--model", "/nope.json"]).is_err());
    assert!(
        run(&["experiment", "table1", "--scale", "7", "--quick"]).is_err()
    );
}
