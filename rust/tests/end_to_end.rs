//! End-to-end integration: fit -> save -> load -> serve round-trips, the
//! online lifecycle (incremental refresh ≡ batch refit, non-blocking hot
//! swap), the experiment drivers at smoke scale, and the CLI surface.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use rskpca::classify::{accuracy, KnnClassifier};
use rskpca::config::ServiceConfig;
use rskpca::coordinator::{
    serve, EmbeddingService, ModelRegistry, DEFAULT_MODEL,
};
use rskpca::data::{gaussian_mixture_2d, train_test_split};
use rskpca::density::{RsdeEstimator, ShadowDensity, StreamingShadow};
use rskpca::experiments::{self, dataset_by_name, sigma_for, ExperimentCtx};
use rskpca::kernel::Kernel;
use rskpca::kpca::{
    fit_kpca, fit_kpca_with, fit_rskpca, fit_rskpca_with, EigSolver,
    EmbeddingModel, GramCache,
};
use rskpca::linalg::Matrix;
use rskpca::runtime::{GramBackend, NativeBackend};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("rskpca_e2e_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn fit_save_load_serve_roundtrip() {
    let ds = dataset_by_name("german", 0.3, 7).unwrap();
    let (train, test) = train_test_split(&ds, 0.8, 1);
    let kernel = Kernel::gaussian(sigma_for(&ds));
    let rs = ShadowDensity::new(4.0).reduce(&train.x, &kernel);
    let model = fit_rskpca(&rs, &kernel, 5).unwrap();
    let expect = model.transform(&test.x);

    // save -> load
    let path = tmpdir("roundtrip").join("model.json");
    model.save(&path).unwrap();
    let loaded = EmbeddingModel::load(&path).unwrap();

    // serve the loaded model
    let svc = serve(
        loaded,
        Box::new(|| Ok(Box::new(NativeBackend::new()))),
        ServiceConfig::default(),
    )
    .unwrap();
    let got = svc.handle().embed(test.x.clone()).unwrap();
    assert!(got.sub(&expect).unwrap().max_abs() < 1e-9);
    svc.shutdown();
}

#[test]
fn rskpca_embeddings_classify_comparably_to_kpca() {
    // The headline behavioural claim at small scale: RSKPCA's embedding
    // is as useful for classification as full KPCA's while retaining a
    // fraction of the data.
    let ds = dataset_by_name("pendigits", 0.2, 3).unwrap();
    let (train, test) = train_test_split(&ds, 0.85, 2);
    let kernel = Kernel::gaussian(sigma_for(&ds));
    let full = fit_kpca(&train.x, &kernel, 5).unwrap();
    let rs = ShadowDensity::new(4.0).reduce(&train.x, &kernel);
    assert!(rs.retention() < 0.9, "no compression at ell=4");
    let reduced = fit_rskpca(&rs, &kernel, 5).unwrap();

    let acc = |model: &EmbeddingModel| {
        let zt = model.transform(&train.x);
        let zs = model.transform(&test.x);
        let knn = KnnClassifier::fit(zt, train.y.clone(), 3);
        accuracy(&knn.predict(&zs), &test.y)
    };
    let acc_full = acc(&full);
    let acc_red = acc(&reduced);
    assert!(
        acc_red >= acc_full - 0.08,
        "rskpca acc {acc_red} much worse than kpca {acc_full}"
    );
}

#[test]
fn incremental_refresh_matches_batch_fit() {
    // Stream a fixed dataset in chunks, `refresh` after each delta
    // batch, and check the final model against a from-scratch
    // `fit_rskpca` on the same reduced set: the incremental path
    // maintains the Gram to norm-trick rounding of the batch engine
    // (~1e-15 on this data), so agreement stays to solver roundoff —
    // well inside the 1e-10 acceptance bound.
    let ds = gaussian_mixture_2d(600, 3, 0.4, 11);
    let kernel = Kernel::gaussian(1.0);
    let mut stream = StreamingShadow::new(&kernel, 4.0, 2);
    for i in 0..150 {
        stream.observe(ds.x.row(i));
    }
    stream.drain_delta(); // consume the initial window
    let mut model = fit_rskpca(&stream.snapshot(), &kernel, 4).unwrap();
    let mut cache = GramCache::new(&kernel, &model.centers);
    for chunk in 1..4 {
        for i in (chunk * 150)..((chunk + 1) * 150) {
            stream.observe(ds.x.row(i));
        }
        let delta = stream.drain_delta();
        model.refresh(&delta, &mut cache, 4).unwrap();
        assert_eq!(model.meta.version, chunk as u64);
    }
    let batch = fit_rskpca(&stream.snapshot(), &kernel, 4).unwrap();
    assert_eq!(model.n_retained(), batch.n_retained());
    assert!(
        model.centers.sub(&batch.centers).unwrap().max_abs() < 1e-12,
        "center replay diverged"
    );
    for (a, b) in model.op_eigenvalues.iter().zip(&batch.op_eigenvalues)
    {
        assert!((a - b).abs() < 1e-10, "eigenvalues {a} vs {b}");
    }
    assert!(
        model.coeffs.sub(&batch.coeffs).unwrap().max_abs() < 1e-10,
        "coefficients diverged: {}",
        model.coeffs.sub(&batch.coeffs).unwrap().max_abs()
    );
    let z_inc = model.transform(&ds.x);
    let z_batch = batch.transform(&ds.x);
    assert!(z_inc.sub(&z_batch).unwrap().max_abs() < 1e-10);
}

/// A backend whose every call sleeps — lets the test publish a new model
/// while a batch is provably in flight.
struct SlowBackend {
    delay: Duration,
}

impl GramBackend for SlowBackend {
    fn gram(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        kernel: &Kernel,
    ) -> rskpca::Result<Matrix> {
        std::thread::sleep(self.delay);
        Ok(kernel.gram(x, y))
    }
    fn name(&self) -> &'static str {
        "slow"
    }
}

#[test]
fn hot_swap_is_non_blocking_and_versioned() {
    let ds = gaussian_mixture_2d(80, 3, 0.4, 21);
    let kernel = Kernel::gaussian(1.0);
    let model = fit_kpca(&ds.x, &kernel, 3).unwrap();
    let flipped = EmbeddingModel {
        coeffs: model.coeffs.scale(-1.0),
        ..model.clone()
    };
    let query = ds.x.select_rows(&(0..8).collect::<Vec<_>>());
    let expect_old = model.transform(&query);
    let expect_new = expect_old.scale(-1.0);

    let registry = Arc::new(ModelRegistry::new());
    registry.publish(DEFAULT_MODEL, model);
    let svc = EmbeddingService::start_with_registry(
        registry.clone(),
        DEFAULT_MODEL,
        Box::new(|| {
            Ok(Box::new(SlowBackend {
                delay: Duration::from_millis(250),
            }) as Box<dyn GramBackend>)
        }),
        ServiceConfig {
            max_batch: 8,
            max_wait_us: 500,
            queue_depth: 64,
            workers: 1,
        },
    )
    .unwrap();
    let h = svc.handle();
    // Enqueue a request; the worker picks it up and enters the slow
    // backend call holding the v1 model Arc.
    let in_flight = h.try_embed(query.clone()).unwrap();
    std::thread::sleep(Duration::from_millis(60));
    // Publish v2 while that batch is mid-execution: must not block.
    let v2 = registry.publish(DEFAULT_MODEL, flipped);
    assert_eq!(v2, 2);
    // A post-swap request is served by the next batch, against v2.
    let z_new = h.embed(query.clone()).unwrap();
    // The in-flight batch completed against the model it fetched (v1).
    let z_old = in_flight.recv().unwrap().unwrap();
    assert!(
        z_old.sub(&expect_old).unwrap().max_abs() < 1e-9,
        "in-flight request must complete against the old model"
    );
    assert!(
        z_new.sub(&expect_new).unwrap().max_abs() < 1e-9,
        "post-swap request must see the new model"
    );
    let snap = svc.shutdown();
    assert_eq!(snap.model_swaps, 1);
    assert_eq!(snap.model_version, 2);
}

#[test]
fn auto_policy_embeddings_match_exact_within_1e8() {
    // The default `Auto` solver must produce the same model as the
    // exact path to 1e-8 at the embedding level whenever its residual
    // gate accepts the truncated solve.  n = 240 with r = 3 clears the
    // Auto crossover (truncated regime), and the clustered Gram's
    // leading spectrum converges the gate comfortably (validated
    // against the exact-PRNG spectrum: residual ~4e-11 in ~14 sweeps).
    let ds = gaussian_mixture_2d(240, 3, 0.4, 21);
    let kernel = Kernel::gaussian(1.0);
    let exact =
        fit_kpca_with(&ds.x, &kernel, 3, &EigSolver::Exact).unwrap();
    let auto =
        fit_kpca_with(&ds.x, &kernel, 3, &EigSolver::Auto).unwrap();
    assert_eq!(auto.meta.solver, EigSolver::Auto);
    assert_eq!(auto.r(), exact.r());
    for j in 0..exact.r() {
        let rel = (exact.op_eigenvalues[j] - auto.op_eigenvalues[j])
            .abs()
            / exact.op_eigenvalues[j];
        assert!(rel < 1e-9, "eigenvalue {j} rel {rel}");
    }
    // Embeddings agree to 1e-8 up to the per-column sign ambiguity of
    // eigenvectors.
    let ze = exact.transform(&ds.x);
    let za = auto.transform(&ds.x);
    for j in 0..exact.r() {
        let sign = if (ze.get(0, j) - za.get(0, j)).abs()
            < (ze.get(0, j) + za.get(0, j)).abs()
        {
            1.0
        } else {
            -1.0
        };
        for i in 0..ds.x.rows() {
            let dev = (ze.get(i, j) - sign * za.get(i, j)).abs();
            assert!(dev < 1e-8, "col {j} row {i}: dev {dev:e}");
        }
    }

    // The weighted (RSKPCA) pipeline under Auto: small reduced sets sit
    // below the crossover, so Auto is exactly the exact path there —
    // bitwise-equal models.
    let rs = ShadowDensity::new(4.0).reduce(&ds.x, &kernel);
    assert!(rs.m() < 128, "reduced set unexpectedly large: {}", rs.m());
    let r_exact =
        fit_rskpca_with(&rs, &kernel, 3, &EigSolver::Exact).unwrap();
    let r_auto =
        fit_rskpca_with(&rs, &kernel, 3, &EigSolver::Auto).unwrap();
    assert_eq!(
        r_auto.coeffs.as_slice(),
        r_exact.coeffs.as_slice(),
        "sub-crossover Auto must be the exact path"
    );
    assert_eq!(r_auto.op_eigenvalues, r_exact.op_eigenvalues);
}

#[test]
fn experiment_drivers_smoke_at_tiny_scale() {
    let mut ctx = ExperimentCtx::quick();
    ctx.out_dir = tmpdir("experiments");
    ctx.scale = 0.05;
    ctx.runs = 1;
    ctx.ell_step = 2.0;
    for exp in ["fig2", "fig4", "fig7", "table2"] {
        experiments::run(exp, &ctx)
            .unwrap_or_else(|e| panic!("{exp} failed: {e}"));
    }
    assert!(ctx
        .out_dir
        .join("fig2_eigenembedding_german.csv")
        .exists());
    assert!(ctx
        .out_dir
        .join("fig4_classification_usps.csv")
        .exists());
    assert!(ctx.out_dir.join("fig7_rsde_schemes_usps.csv").exists());
    assert!(ctx.out_dir.join("table2_cost.csv").exists());
    // CSVs have headers + at least one data row.
    for f in [
        "fig2_eigenembedding_german.csv",
        "fig4_classification_usps.csv",
    ] {
        let text =
            std::fs::read_to_string(ctx.out_dir.join(f)).unwrap();
        assert!(text.lines().count() >= 2, "{f} empty");
    }
}

#[test]
fn cli_fit_and_embed_commands_compose() {
    let dir = tmpdir("cli");
    let cfg_path = dir.join("run.toml");
    std::fs::write(
        &cfg_path,
        "[run]\ndataset = \"gmm2d\"\nell = 4.0\nrank = 3\n",
    )
    .unwrap();
    let model_path = dir.join("model.json");
    let data_path = dir.join("data.csv");
    let emb_path = dir.join("emb.csv");

    let run = |args: &[&str]| {
        rskpca::cli::dispatch(
            &args.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        )
    };
    run(&[
        "fit",
        "--config",
        cfg_path.to_str().unwrap(),
        "--model-out",
        model_path.to_str().unwrap(),
    ])
    .unwrap();
    run(&[
        "gen",
        "--dataset",
        "gmm2d",
        "--out",
        data_path.to_str().unwrap(),
        "--seed",
        "3",
    ])
    .unwrap();
    run(&[
        "embed",
        "--model",
        model_path.to_str().unwrap(),
        "--data",
        data_path.to_str().unwrap(),
        "--out",
        emb_path.to_str().unwrap(),
    ])
    .unwrap();
    let emb = std::fs::read_to_string(&emb_path).unwrap();
    assert_eq!(emb.lines().count(), 1000);
    // label,z0,z1,z2 per line.
    assert_eq!(emb.lines().next().unwrap().split(',').count(), 4);

    // serve --selftest drives the loaded model end to end in-process
    // (plain `serve` now blocks on the HTTP listener; the network path
    // is covered by tests/server_http.rs).
    run(&[
        "serve",
        "--model",
        model_path.to_str().unwrap(),
        "--selftest",
        "--requests",
        "20",
        "--rows-per-request",
        "4",
    ])
    .unwrap();

    // serve --refresh: the background refresher observes the traffic and
    // hot-swaps the served model mid-run.
    run(&[
        "serve",
        "--model",
        model_path.to_str().unwrap(),
        "--selftest",
        "--requests",
        "40",
        "--rows-per-request",
        "4",
        "--refresh",
        "10",
    ])
    .unwrap();
}

#[test]
fn cli_rejects_bad_invocations() {
    let run = |args: &[&str]| {
        rskpca::cli::dispatch(
            &args.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        )
    };
    assert!(run(&["experiment"]).is_err()); // missing name
    assert!(run(&["experiment", "fig99", "--quick"]).is_err());
    assert!(run(&["fit"]).is_err()); // missing flags
    assert!(run(&["embed", "--model", "/nope.json"]).is_err());
    assert!(
        run(&["experiment", "table1", "--scale", "7", "--quick"]).is_err()
    );
}
