//! Integration: the PJRT backend executing real AOT artifacts must agree
//! with the native kernel path, end to end (manifest -> HLO text ->
//! compile -> pad -> execute -> unpad).
//!
//! Requires `make artifacts`; tests skip (with a note) if the artifacts
//! directory is absent so `cargo test` stays runnable in a fresh checkout.
//!
//! When the crate is built without the `pjrt` cargo feature (the default
//! — the real backend needs the vendored xla bindings), every test here
//! is `#[ignore]`d: the stub backend cannot execute artifacts, so running
//! them would only exercise the stub's error path.

use std::path::{Path, PathBuf};

use rskpca::data::gaussian_mixture_2d;
use rskpca::kernel::Kernel;
use rskpca::kpca::fit_kpca;
use rskpca::linalg::Matrix;
use rskpca::prng::Pcg64;
use rskpca::runtime::{GramBackend, NativeBackend, PjrtBackend};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed);
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            m.set(i, j, rng.normal());
        }
    }
    m
}

fn max_rel_dev(a: &Matrix, b: &Matrix) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            let dev = (a.get(i, j) - b.get(i, j)).abs()
                / (1.0 + a.get(i, j).abs());
            worst = worst.max(dev);
        }
    }
    worst
}

#[test]
#[cfg_attr(
    not(feature = "pjrt"),
    ignore = "environment-dependent: needs the `pjrt` feature (xla \
              bindings) and `make artifacts`"
)]
fn pjrt_gram_matches_native_across_buckets() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut native = NativeBackend::new();
    // Sweep odd shapes that exercise row chunking, m/d padding, and the
    // d-bucket boundaries (32 / 256 / 576 lattice).
    for (n, m, d, sigma, seed) in [
        (10usize, 7usize, 3usize, 1.0f64, 1u64),
        (300, 100, 24, 30.0, 2),   // german-like: row chunking + d=32
        (64, 128, 16, 120.0, 3),   // exact m bucket
        (33, 200, 40, 5.0, 4),     // d > 32 -> d=256 bucket
        (20, 60, 300, 10.0, 5),    // d > 256 -> d=576 bucket
    ] {
        let x = random_matrix(n, d, seed);
        let y = random_matrix(m, d, seed + 100);
        let k = Kernel::gaussian(sigma);
        let got = pjrt.gram(&x, &y, &k).unwrap();
        let expect = native.gram(&x, &y, &k).unwrap();
        assert_eq!(got.rows(), n);
        assert_eq!(got.cols(), m);
        let dev = max_rel_dev(&expect, &got);
        assert!(dev < 1e-4, "gram n={n} m={m} d={d}: max rel dev {dev}");
    }
}

#[test]
#[cfg_attr(
    not(feature = "pjrt"),
    ignore = "environment-dependent: needs the `pjrt` feature (xla \
              bindings) and `make artifacts`"
)]
fn pjrt_gram_laplacian_artifacts_work() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut native = NativeBackend::new();
    let x = random_matrix(50, 20, 7);
    let y = random_matrix(30, 20, 8);
    let k = Kernel::laplacian(3.0);
    let got = pjrt.gram(&x, &y, &k).unwrap();
    let expect = native.gram(&x, &y, &k).unwrap();
    let dev = max_rel_dev(&expect, &got);
    assert!(dev < 1e-3, "laplacian max rel dev {dev}");
}

#[test]
#[cfg_attr(
    not(feature = "pjrt"),
    ignore = "environment-dependent: needs the `pjrt` feature (xla \
              bindings) and `make artifacts`"
)]
fn pjrt_embed_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut native = NativeBackend::new();
    for (n, m, d, r, seed) in [
        (40usize, 25usize, 6usize, 5usize, 11u64),
        (300, 90, 24, 16, 12), // full rank bucket + row chunking
        (10, 700, 10, 4, 13),  // centers wider than one embed bucket? no:
                               // 700 <= 1024 bucket — padded not chunked
    ] {
        let x = random_matrix(n, d, seed);
        let c = random_matrix(m, d, seed + 1);
        let a = random_matrix(m, r, seed + 2).scale(0.3);
        let k = Kernel::gaussian(4.0);
        let got = pjrt.embed(&x, &c, &a, &k).unwrap();
        let expect = native.embed(&x, &c, &a, &k).unwrap();
        assert_eq!(got.rows(), n);
        assert_eq!(got.cols(), r);
        let dev = max_rel_dev(&expect, &got);
        assert!(dev < 1e-4, "embed n={n} m={m} d={d} r={r}: dev {dev}");
    }
}

#[test]
#[cfg_attr(
    not(feature = "pjrt"),
    ignore = "environment-dependent: needs the `pjrt` feature (xla \
              bindings) and `make artifacts`"
)]
fn pjrt_embed_chunks_very_wide_center_sets() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let mut native = NativeBackend::new();
    // 1500 centers > largest (1024) embed bucket -> chunk + accumulate.
    let x = random_matrix(17, 8, 21);
    let c = random_matrix(1500, 8, 22);
    let a = random_matrix(1500, 3, 23).scale(0.1);
    let k = Kernel::gaussian(2.0);
    let got = pjrt.embed(&x, &c, &a, &k).unwrap();
    let expect = native.embed(&x, &c, &a, &k).unwrap();
    let dev = max_rel_dev(&expect, &got);
    assert!(dev < 1e-3, "wide embed dev {dev}");
    assert!(pjrt.executions > 1, "expected chunked execution");
}

#[test]
#[cfg_attr(
    not(feature = "pjrt"),
    ignore = "environment-dependent: needs the `pjrt` feature (xla \
              bindings) and `make artifacts`"
)]
fn pjrt_serves_a_fitted_model_through_the_coordinator() {
    let Some(dir) = artifacts_dir() else { return };
    // Fit RSKPCA natively, then serve through the PJRT path and check the
    // service output against the native transform.
    let ds = gaussian_mixture_2d(200, 3, 0.4, 31);
    let k = Kernel::gaussian(1.0);
    let rs = rskpca::density::ShadowDensity::new(4.0).fit(&ds.x, &k);
    let model = rskpca::kpca::fit_rskpca(&rs, &k, 4).unwrap();
    let expect = model.transform(&ds.x);

    let cfg = rskpca::config::ServiceConfig::default();
    let svc = rskpca::coordinator::serve(
        model,
        rskpca::runtime::factory_from_name("pjrt", &dir),
        cfg,
    )
    .unwrap();
    let got = svc.handle().embed(ds.x.clone()).unwrap();
    let dev = max_rel_dev(&expect, &got);
    assert!(dev < 1e-4, "service dev {dev}");
    let snap = svc.shutdown();
    assert_eq!(snap.rows, 200);
}

#[test]
#[cfg_attr(
    not(feature = "pjrt"),
    ignore = "environment-dependent: needs the `pjrt` feature (xla \
              bindings) and `make artifacts`"
)]
fn pjrt_rejects_rank_beyond_bucket() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let x = random_matrix(8, 4, 41);
    let c = random_matrix(8, 4, 42);
    let a = random_matrix(8, 17, 43); // k bucket is 16
    let k = Kernel::gaussian(1.0);
    assert!(pjrt.embed(&x, &c, &a, &k).is_err());
}

#[test]
#[cfg_attr(
    not(feature = "pjrt"),
    ignore = "environment-dependent: needs the `pjrt` feature (xla \
              bindings) and `make artifacts`"
)]
fn full_kpca_model_served_via_pjrt_uses_gram_chunking() {
    let Some(dir) = artifacts_dir() else { return };
    // Full KPCA retains all n=1200 centers (> 1024 bucket) — exercises the
    // wide-center chunked embed path with a real model.
    let ds = gaussian_mixture_2d(1200, 3, 0.4, 51);
    let k = Kernel::gaussian(1.0);
    let model = fit_kpca(&ds.x, &k, 3).unwrap();
    let probe = ds.x.select_rows(&(0..30).collect::<Vec<_>>());
    let expect = model.transform(&probe);
    let mut pjrt = PjrtBackend::load(&dir).unwrap();
    let got = pjrt
        .embed(&probe, &model.centers, &model.coeffs, &model.kernel)
        .unwrap();
    let dev = max_rel_dev(&expect, &got);
    assert!(dev < 1e-3, "chunked full-KPCA dev {dev}");
}
