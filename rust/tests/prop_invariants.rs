//! Property-based invariants (in-tree harness; see `rskpca::testutil`).
//!
//! Covers the invariants DESIGN.md §7 calls out: shadow-set partition
//! properties for random data/σ/ℓ, eigensolver residuals and
//! orthonormality, RSKPCA eigenvalues within the Thm 5.2 bound, MMD within
//! the Thm 5.1 bound, and coordinator routing/batching/state conservation
//! under random request mixes.

use rskpca::config::ServiceConfig;
use rskpca::coordinator::EmbeddingService;
use rskpca::density::{ReducedSet, RsdeEstimator, ShadowDensity};
use rskpca::kernel::Kernel;
use rskpca::kpca::{fit_kpca, fit_rskpca};
use rskpca::linalg::{eigh, euclidean, Matrix};
use rskpca::mmd::{mmd_reduced_set, thm51_mmd_bound};
use rskpca::runtime::NativeBackend;
use rskpca::testutil::prop_check;

#[derive(Debug)]
struct ShadowCase {
    x: Matrix,
    sigma: f64,
    ell: f64,
}

fn shadow_case(g: &mut rskpca::testutil::GenCtx) -> ShadowCase {
    let n = g.usize_in(5, 120);
    let d = g.usize_in(1, 6);
    let x = g.matrix(n, d);
    ShadowCase {
        x,
        sigma: g.f64_in(0.05, 3.0),
        ell: g.f64_in(0.5, 8.0),
    }
}

#[test]
fn prop_shadow_sets_partition_and_cover() {
    prop_check("shadow_partition", 60, shadow_case, |case| {
        let kernel = Kernel::gaussian(case.sigma);
        let rs = ShadowDensity::new(case.ell).reduce(&case.x, &kernel);
        if !rs.check_invariants() {
            return Err("weight invariants violated".into());
        }
        let assignment = rs
            .assignment
            .as_ref()
            .ok_or("shadow must record assignment")?;
        if assignment.len() != case.x.rows() {
            return Err("assignment not total".into());
        }
        let eps = kernel.shadow_radius(case.ell);
        // Cover: every point within eps of its center.
        for i in 0..case.x.rows() {
            let c = rs.centers.row(assignment[i]);
            if euclidean(case.x.row(i), c) >= eps {
                return Err(format!("point {i} outside its shadow"));
            }
        }
        // Partition: weights equal cell counts.
        let mut counts = vec![0.0; rs.m()];
        for &a in assignment {
            counts[a] += 1.0;
        }
        if counts != rs.weights {
            return Err("weights != cell sizes".into());
        }
        // Separation: centers pairwise >= eps apart.
        for i in 0..rs.m() {
            for j in (i + 1)..rs.m() {
                if euclidean(rs.centers.row(i), rs.centers.row(j))
                    < eps - 1e-12
                {
                    return Err(format!("centers {i},{j} too close"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_eigh_residual_and_orthonormality() {
    prop_check(
        "eigh_residuals",
        40,
        |g| {
            let n = g.usize_in(1, 24);
            let b = g.matrix(n, n);
            b.add(&b.transpose()).unwrap().scale(0.5)
        },
        |a| {
            let n = a.rows();
            let e = eigh(a).map_err(|e| e.to_string())?;
            let tol = 1e-7 * (n as f64).max(1.0);
            for i in 0..n {
                let v = e.vectors.col(i);
                let av = a.matvec(&v).unwrap();
                for r in 0..n {
                    if (av[r] - e.values[i] * v[r]).abs() > tol {
                        return Err(format!(
                            "residual {} at pair {i}",
                            (av[r] - e.values[i] * v[r]).abs()
                        ));
                    }
                }
            }
            let vtv = e.vectors.transpose().matmul(&e.vectors).unwrap();
            let dev =
                vtv.sub(&Matrix::identity(n)).unwrap().max_abs();
            if dev > tol {
                return Err(format!("not orthonormal: {dev}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_thm51_mmd_bound_holds() {
    prop_check("thm51_bound", 40, shadow_case, |case| {
        let kernel = Kernel::gaussian(case.sigma);
        let rs = ShadowDensity::new(case.ell).reduce(&case.x, &kernel);
        let measured = mmd_reduced_set(&case.x, &rs, &kernel);
        let bound = thm51_mmd_bound(&kernel, case.ell);
        if measured > bound + 1e-9 {
            return Err(format!("MMD {measured} > bound {bound}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rskpca_spectrum_dominated_by_kpca_spectrum() {
    // The weighted surrogate's spectrum must stay within the kernel's
    // global bounds: 0 <= lambda~ <= kappa, and total mass <= kappa.
    prop_check("rskpca_spectrum", 30, shadow_case, |case| {
        if case.x.rows() < 4 {
            return Ok(());
        }
        let kernel = Kernel::gaussian(case.sigma);
        let rs = ShadowDensity::new(case.ell).reduce(&case.x, &kernel);
        let model = fit_rskpca(&rs, &kernel, 3).map_err(|e| e.to_string())?;
        let total: f64 = model.op_eigenvalues.iter().sum();
        for &l in &model.op_eigenvalues {
            if !(0.0..=kernel.kappa() + 1e-9).contains(&l) {
                return Err(format!("eigenvalue {l} out of range"));
            }
        }
        if total > kernel.kappa() + 1e-9 {
            return Err(format!("trace {total} exceeds kappa"));
        }
        Ok(())
    });
}

#[test]
fn prop_degenerate_rskpca_matches_kpca_eigenvalues() {
    prop_check(
        "degenerate_rskpca",
        20,
        |g| {
            let n = g.usize_in(4, 40);
            let d = g.usize_in(1, 4);
            (g.matrix(n, d), g.f64_in(0.3, 2.0))
        },
        |(x, sigma)| {
            let kernel = Kernel::gaussian(*sigma);
            let full = fit_kpca(x, &kernel, 3).map_err(|e| e.to_string())?;
            let rs = ReducedSet {
                centers: x.clone(),
                weights: vec![1.0; x.rows()],
                n_source: x.rows(),
                assignment: Some((0..x.rows()).collect()),
                method: "degenerate".into(),
            };
            let red = fit_rskpca(&rs, &kernel, 3).map_err(|e| e.to_string())?;
            for (a, b) in
                full.op_eigenvalues.iter().zip(&red.op_eigenvalues)
            {
                if (a - b).abs() > 1e-8 {
                    return Err(format!("eigenvalue mismatch {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrips_arbitrary_documents() {
    use rskpca::ser::Json;
    fn gen_json(g: &mut rskpca::testutil::GenCtx, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.usize_in(0, 1) == 1),
            2 => Json::Num((g.normal() * 1e3).round() / 8.0),
            3 => Json::Str(
                (0..g.usize_in(0, 12))
                    .map(|i| {
                        // Mix in escapes and non-ascii.
                        ['a', '"', '\\', '\n', 'ß', '7', ' '][(i
                            + g.usize_in(0, 6))
                            % 7]
                    })
                    .collect(),
            ),
            4 => Json::Arr(
                (0..g.usize_in(0, 4))
                    .map(|_| gen_json(g, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    prop_check(
        "json_roundtrip",
        100,
        |g| gen_json(g, 3),
        |doc| {
            let text = doc.to_string();
            let back = rskpca::ser::parse(&text)
                .map_err(|e| format!("reparse failed: {e} for {text}"))?;
            if &back != doc {
                return Err(format!("roundtrip mismatch: {text}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_toml_parser_never_panics() {
    // Fuzz-ish: arbitrary line soup must parse or error, never panic.
    prop_check(
        "toml_no_panic",
        120,
        |g| {
            let tokens = [
                "[sec]", "[', '", "a = 1", "b = \"x\"", "c = [1, 2]",
                "= 3", "weird", "# comment", "d = true", "e = [",
                "f = \"unterminated", "[s2]", "g = 1e300", "h = -0.5",
            ];
            (0..g.usize_in(0, 10))
                .map(|_| tokens[g.usize_in(0, tokens.len() - 1)])
                .collect::<Vec<_>>()
                .join("\n")
        },
        |doc| {
            let _ = rskpca::config::TomlDoc::parse(doc); // must not panic
            Ok(())
        },
    );
}

#[test]
fn prop_model_json_roundtrip_preserves_transform() {
    prop_check(
        "model_roundtrip",
        12,
        |g| {
            let n = g.usize_in(5, 40);
            let d = g.usize_in(1, 5);
            (g.matrix(n, d), g.f64_in(0.3, 3.0))
        },
        |(x, sigma)| {
            let kernel = Kernel::gaussian(*sigma);
            let model =
                fit_kpca(x, &kernel, 3).map_err(|e| e.to_string())?;
            let back = rskpca::kpca::EmbeddingModel::from_json(
                &model.to_json(),
            )
            .map_err(|e| e.to_string())?;
            let z1 = model.transform(x);
            let z2 = back.transform(x);
            if z1.sub(&z2).unwrap().max_abs() > 1e-9 {
                return Err("transform changed after roundtrip".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_service_conserves_rows_and_order() {
    // Coordinator state invariant: any random mix of request sizes gets
    // back exactly its own rows, embedded correctly, in order.
    prop_check(
        "service_conservation",
        8,
        |g| {
            let n = g.usize_in(30, 80);
            let x = g.matrix(n, 3);
            let sizes: Vec<usize> = (0..g.usize_in(1, 12))
                .map(|_| g.usize_in(1, 9))
                .collect();
            let max_batch = g.usize_in(1, 32);
            (x, sizes, max_batch)
        },
        |(x, sizes, max_batch)| {
            let kernel = Kernel::gaussian(1.0);
            let model =
                fit_kpca(x, &kernel, 2).map_err(|e| e.to_string())?;
            let expect = model.transform(x);
            let svc = EmbeddingService::start(
                model,
                Box::new(|| Ok(Box::new(NativeBackend::new()))),
                ServiceConfig {
                    max_batch: *max_batch,
                    max_wait_us: 200,
                    queue_depth: 64,
                    workers: 1,
                },
            )
            .map_err(|e| e.to_string())?;
            let h = svc.handle();
            let mut receivers = Vec::new();
            let mut at = 0usize;
            for &s in sizes {
                let s = s.min(x.rows() - 1);
                let start = at % (x.rows() - s);
                at += 13;
                let idx: Vec<usize> = (start..start + s).collect();
                receivers.push((
                    idx.clone(),
                    h.try_embed(x.select_rows(&idx))
                        .map_err(|e| e.to_string())?,
                ));
            }
            let mut total = 0usize;
            for (idx, rx) in receivers {
                let got = rx
                    .recv()
                    .map_err(|e| e.to_string())?
                    .map_err(|e| e.to_string())?;
                if got.rows() != idx.len() {
                    return Err("row count changed".into());
                }
                total += got.rows();
                for (r, &orig) in idx.iter().enumerate() {
                    for c in 0..got.cols() {
                        if (got.get(r, c) - expect.get(orig, c)).abs()
                            > 1e-9
                        {
                            return Err(format!(
                                "row {orig} embedded wrong"
                            ));
                        }
                    }
                }
            }
            let snap = svc.shutdown();
            if snap.rows != total as u64 {
                return Err(format!(
                    "service counted {} rows, clients got {total}",
                    snap.rows
                ));
            }
            Ok(())
        },
    );
}
