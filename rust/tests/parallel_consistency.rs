//! Parallel-engine consistency: every parallel hot path must be
//! **bitwise thread-count invariant** across {1, 2, 8} (each output
//! element is produced by the same strict-k-order operation sequence at
//! any thread count), agree with its retained naive `*_serial`
//! cross-check reference to <= 1e-10, and fixed seeds must give
//! bit-identical results run to run.
//!
//! The GEMM/norm-trick engine reorders flops relative to the naive
//! references (register tiling, the ||x||²+||y||²-2·x·y identity), so
//! fast-vs-naive agreement is a rounding bound, not equality; the
//! thread-count invariance of the fast path itself stays exact.  The
//! chunked reductions (MMD sums) additionally re-associate across
//! chunks and agree within <= 1e-10.
//!
//! The tests mutate the process-global thread setting
//! (`parallel::set_threads`), so they serialize on a local mutex and
//! restore the auto default on exit.

use std::sync::{Mutex, MutexGuard};

use rskpca::classify::KnnClassifier;
use rskpca::data::gaussian_mixture_2d;
use rskpca::density::{RsdeEstimator, ShadowDensity};
use rskpca::kernel::{Kernel, Scratch};
use rskpca::kpca::{fit_kpca, fit_nystrom, fit_rskpca};
use rskpca::linalg::{eigh, eigh_serial, jacobi_eigh, subspace_eigh};
use rskpca::mmd::mmd_weighted;
use rskpca::parallel;
use rskpca::testutil::{prop_check, random_matrix};

static THREAD_LOCK: Mutex<()> = Mutex::new(());

/// Serialize tests that flip the global thread count; recover from
/// poisoning so one failure doesn't cascade.
fn lock() -> MutexGuard<'static, ()> {
    THREAD_LOCK
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Run `f` once per thread count in {1, 2, 8}, restoring auto after.
fn for_thread_counts(mut f: impl FnMut(usize)) {
    for &t in &[1usize, 2, 8] {
        parallel::set_threads(t);
        f(t);
    }
    parallel::set_threads(0);
}

#[test]
fn gram_paths_bitwise_invariant_and_match_serial() {
    let _g = lock();
    // Big enough that the parallel bands engage at t >= 2.
    let x = random_matrix(130, 6, 1);
    let y = random_matrix(85, 6, 2);
    for kernel in [
        Kernel::gaussian(1.1),
        Kernel::laplacian(0.8),
        Kernel::cauchy(1.9),
    ] {
        let gram_ref = kernel.gram_serial(&x, &y);
        let sym_ref = kernel.gram_sym_serial(&x);
        parallel::set_threads(1);
        let gram_t1 = kernel.gram(&x, &y);
        let sym_t1 = kernel.gram_sym(&x);
        // Norm-trick engine vs the naive pair-by-pair reference: the
        // 1e-10 contract.
        let dev = gram_t1.sub(&gram_ref).unwrap().max_abs();
        assert!(dev <= 1e-10, "gram {:?} dev {dev:e}", kernel.kind);
        let dev = sym_t1.sub(&sym_ref).unwrap().max_abs();
        assert!(dev <= 1e-10, "gram_sym {:?} dev {dev:e}", kernel.kind);
        // And the engine itself is bitwise thread-count invariant.
        for_thread_counts(|t| {
            assert_eq!(
                kernel.gram(&x, &y),
                gram_t1,
                "gram {:?} at t={t}",
                kernel.kind
            );
            assert_eq!(
                kernel.gram_sym(&x),
                sym_t1,
                "gram_sym {:?} at t={t}",
                kernel.kind
            );
        });
    }
}

#[test]
fn prop_gram_sym_parallel_matches_serial() {
    let _g = lock();
    prop_check(
        "gram_sym_parallel",
        25,
        |g| {
            // Lower bound 70 keeps n^2 above the parallel threshold so
            // the banded path actually runs (the size hint caps n near
            // 102).
            let n = g.usize_in(70, 120);
            let d = g.usize_in(1, 5);
            (g.matrix(n, d), g.f64_in(0.3, 3.0))
        },
        |(x, sigma)| {
            let kernel = Kernel::gaussian(*sigma);
            let reference = kernel.gram_sym_serial(x);
            for &t in &[1usize, 2, 8] {
                parallel::set_threads(t);
                let par = kernel.gram_sym(x);
                parallel::set_threads(0);
                let dev = par.sub(&reference).unwrap().max_abs();
                if dev > 1e-10 {
                    return Err(format!(
                        "t={t}: max dev {dev} (n={})",
                        x.rows()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn matmul_and_matvec_thread_count_invariant() {
    let _g = lock();
    let a = random_matrix(70, 90, 3);
    let bm = random_matrix(90, 60, 4);
    let v: Vec<f64> = (0..90).map(|i| (i as f64 * 0.31).cos()).collect();
    parallel::set_threads(1);
    let mm_ref = a.matmul(&bm).unwrap();
    let mt_ref = a.matmul_transb(&random_matrix(50, 90, 5)).unwrap();
    let mv_ref = a.matvec(&v).unwrap();
    // GEMM vs the retained naive serial references (<= 1e-10).
    let dev = mm_ref.sub(&a.matmul_serial(&bm).unwrap()).unwrap().max_abs();
    assert!(dev <= 1e-10, "matmul vs serial ref: {dev:e}");
    let dev = mt_ref
        .sub(&a.matmul_transb_serial(&random_matrix(50, 90, 5)).unwrap())
        .unwrap()
        .max_abs();
    assert!(dev <= 1e-10, "matmul_transb vs serial ref: {dev:e}");
    let mv_serial = a.matvec_serial(&v).unwrap();
    for (x, y) in mv_ref.iter().zip(&mv_serial) {
        assert!((x - y).abs() <= 1e-10, "matvec vs serial ref");
    }
    for_thread_counts(|t| {
        assert_eq!(a.matmul(&bm).unwrap(), mm_ref, "matmul t={t}");
        assert_eq!(
            a.matmul_transb(&random_matrix(50, 90, 5)).unwrap(),
            mt_ref,
            "matmul_transb t={t}"
        );
        assert_eq!(a.matvec(&v).unwrap(), mv_ref, "matvec t={t}");
    });
}

#[test]
fn gemm_matches_naive_across_shapes_and_threads() {
    let _g = lock();
    // {1x1, tall, wide, k=0, non-tile-multiple edges} x threads {1,2,8}:
    // the GEMM path must track the naive triple loop everywhere and be
    // bitwise invariant across thread counts.
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (300, 5, 4),  // tall
        (5, 4, 300),  // wide
        (6, 0, 7),    // k = 0 (empty product)
        (37, 29, 23), // nothing divides the 4x8 tile or KC
        (12, 300, 16),
    ];
    for &(m, k, n) in shapes {
        let a = random_matrix(m, k, (m * 7 + k) as u64);
        let b = random_matrix(k, n, (n * 13 + 1) as u64);
        let bt = random_matrix(n, k, (m + n) as u64);
        let want = a.matmul_serial(&b).unwrap();
        let want_t = a.matmul_transb_serial(&bt).unwrap();
        parallel::set_threads(1);
        let got_t1 = a.matmul(&b).unwrap();
        let got_tb_t1 = a.matmul_transb(&bt).unwrap();
        let dev = got_t1.sub(&want).unwrap().max_abs();
        assert!(dev <= 1e-10, "gemm {m}x{k}x{n}: dev {dev:e}");
        let dev = got_tb_t1.sub(&want_t).unwrap().max_abs();
        assert!(dev <= 1e-10, "gemm_transb {m}x{k}x{n}: dev {dev:e}");
        for_thread_counts(|t| {
            assert_eq!(
                a.matmul(&b).unwrap(),
                got_t1,
                "gemm {m}x{k}x{n} t={t}"
            );
            assert_eq!(
                a.matmul_transb(&bt).unwrap(),
                got_tb_t1,
                "gemm_transb {m}x{k}x{n} t={t}"
            );
        });
    }
}

#[test]
fn serving_scratch_reuse_is_bitwise_stable_and_allocation_free() {
    let _g = lock();
    // The serving hot path: `transform_batch_with` over a reused
    // Scratch must (1) return bitwise-identical output on every call,
    // (2) stop growing its buffers after the warmup call — the
    // steady-state contract of the batch worker (remaining per-call
    // heap traffic is the output matrix + O(threads) fork/join
    // bookkeeping, which this counter intentionally does not track).
    parallel::set_threads(2);
    let train = gaussian_mixture_2d(200, 3, 0.4, 41);
    let kernel = Kernel::gaussian(1.0);
    let model = fit_kpca(&train.x, &kernel, 4).unwrap();
    // 300 x 200 x 2 clears the fused-projection flop threshold, so the
    // banded path (and its per-band scratches) actually engages at t=2.
    let batch = gaussian_mixture_2d(300, 3, 0.4, 42).x;
    let mut scratch = Scratch::new();
    let z0 = model.transform_batch_with(&mut scratch, &batch);
    let warm = scratch.grow_events();
    for round in 0..10 {
        let z = model.transform_batch_with(&mut scratch, &batch);
        assert_eq!(
            z.as_slice(),
            z0.as_slice(),
            "output drifted at round {round}"
        );
    }
    assert_eq!(
        scratch.grow_events(),
        warm,
        "scratch grew after warmup — serving hot loop allocated"
    );
    // The scratch-free path is the same computation.
    assert_eq!(model.transform_batch(&batch).as_slice(), z0.as_slice());
    parallel::set_threads(0);
}

/// `‖A − V·Λ·Vᵀ‖_max` for a full eigendecomposition.
fn reconstruction_dev(a: &rskpca::linalg::Matrix, e: &rskpca::linalg::Eigh)
    -> f64 {
    let ones = vec![1.0; e.vectors.rows()];
    let vl = e.vectors.scale_rows_cols(&ones, &e.values).unwrap();
    let rec = vl.matmul_transb(&e.vectors).unwrap();
    a.sub(&rec).unwrap().max_abs()
}

#[test]
fn blocked_eigh_crosscheck_small_sizes_vs_jacobi() {
    let _g = lock();
    parallel::set_threads(0);
    // Degenerate and single-panel orders: the blocked solver (or its
    // small-order serial delegate) must pin Jacobi's eigenvalues and
    // reconstruct A.
    for (n, seed) in [(1usize, 1u64), (2, 2), (33, 3)] {
        let a = {
            let b = random_matrix(n, n, seed);
            b.add(&b.transpose()).unwrap().scale(0.5)
        };
        let blocked = eigh(&a).unwrap();
        let jac = jacobi_eigh(&a).unwrap();
        for (x, y) in blocked.values.iter().zip(&jac.values) {
            assert!((x - y).abs() <= 1e-9, "n={n}: {x} vs {y}");
        }
        assert!(
            reconstruction_dev(&a, &blocked) <= 1e-9,
            "n={n} reconstruction"
        );
    }
}

#[test]
fn blocked_eigh_crosscheck_vs_serial_across_threads() {
    let _g = lock();
    // The ISSUE-5 acceptance suite: blocked eigh vs the retained serial
    // tred2/tql2 reference on random symmetric matrices — eigenvalue
    // agreement <= 1e-9, reconstruction ||A - QΛQᵀ|| and
    // Q-orthogonality <= 1e-9 — plus bitwise thread-count invariance
    // across {1, 2, 8}.  The expensive 513-order case needs release
    // codegen to finish quickly; the debug `cargo test -q` pass keeps
    // the multi-panel coverage at 200 (ci.sh reruns this suite under
    // --release with the full size set).
    #[cfg(debug_assertions)]
    let sizes: &[usize] = &[200];
    #[cfg(not(debug_assertions))]
    let sizes: &[usize] = &[200, 513];
    for (i, &n) in sizes.iter().enumerate() {
        let a = {
            let b = random_matrix(n, n, 90 + i as u64);
            b.add(&b.transpose()).unwrap().scale(0.5)
        };
        parallel::set_threads(1);
        let blocked = eigh(&a).unwrap();
        let serial = eigh_serial(&a).unwrap();
        for (j, (x, y)) in
            blocked.values.iter().zip(&serial.values).enumerate()
        {
            assert!(
                (x - y).abs() <= 1e-9,
                "n={n} eigenvalue {j}: {x} vs {y}"
            );
        }
        assert!(
            reconstruction_dev(&a, &blocked) <= 1e-9,
            "n={n} blocked reconstruction"
        );
        let q = &blocked.vectors;
        let orth = q
            .transpose()
            .matmul(q)
            .unwrap()
            .sub(&rskpca::linalg::Matrix::identity(n))
            .unwrap()
            .max_abs();
        assert!(orth <= 1e-9, "n={n} Q-orthogonality: {orth:e}");
        // Bitwise thread-count invariance (the numeric checks above
        // then transfer to every thread count for free).
        for_thread_counts(|t| {
            let e = eigh(&a).unwrap();
            assert_eq!(e.values, blocked.values, "n={n} values t={t}");
            assert_eq!(
                e.vectors.as_slice(),
                blocked.vectors.as_slice(),
                "n={n} vectors t={t}"
            );
        });
    }
}

#[test]
fn subspace_eigh_thread_count_invariant_and_correct() {
    let _g = lock();
    let ds = gaussian_mixture_2d(120, 3, 0.4, 6);
    let kernel = Kernel::gaussian(1.0);
    parallel::set_threads(1);
    let gram = kernel.gram_sym(&ds.x).scale(1.0 / 120.0);
    let reference = subspace_eigh(&gram, 4, 300, 1e-13).unwrap();
    for_thread_counts(|t| {
        let e = subspace_eigh(&gram, 4, 300, 1e-13).unwrap();
        assert_eq!(e.values, reference.values, "values t={t}");
        assert_eq!(
            e.vectors.as_slice(),
            reference.vectors.as_slice(),
            "vectors t={t}"
        );
    });
    // And the Ritz pairs really solve the eigenproblem.
    for j in 0..4 {
        let v = reference.vectors.col(j);
        let av = gram.matvec(&v).unwrap();
        for i in 0..v.len() {
            assert!(
                (av[i] - reference.values[j] * v[i]).abs() < 1e-7,
                "residual at pair {j}"
            );
        }
    }
}

#[test]
fn transform_batch_matches_serial_for_all_backbones() {
    let _g = lock();
    // 300 query rows x 150 centers x 2 dims clears the fused-projection
    // flop threshold, so the full-KPCA / Nyström models exercise the
    // parallel bands at t >= 2 (the small RSKPCA center set stays on the
    // serial fast path, which the equality check covers too).
    let train = gaussian_mixture_2d(150, 3, 0.4, 7);
    let test = gaussian_mixture_2d(300, 3, 0.4, 8);
    let kernel = Kernel::gaussian(1.0);
    let rs = ShadowDensity::new(4.0).reduce(&train.x, &kernel);
    parallel::set_threads(1);
    let models = vec![
        fit_kpca(&train.x, &kernel, 4).unwrap(),
        fit_nystrom(&train.x, &kernel, 4, 30, 9).unwrap(),
        fit_rskpca(&rs, &kernel, 4).unwrap(),
    ];
    for model in &models {
        parallel::set_threads(1);
        let reference = model.transform_batch(&test.x);
        // Row i must match the scalar single-point path to the 1e-10
        // contract (the batch path is distance-free, the point path
        // computes per-pair distances).
        for i in (0..test.x.rows()).step_by(29) {
            let zp = model.transform_point(test.x.row(i));
            for j in 0..model.r() {
                assert!(
                    (zp[j] - reference.get(i, j)).abs() <= 1e-10,
                    "{}: point path differs at ({i},{j}): {} vs {}",
                    model.method,
                    zp[j],
                    reference.get(i, j)
                );
            }
        }
        for_thread_counts(|t| {
            assert_eq!(
                model.transform_batch(&test.x),
                reference,
                "{} at t={t}",
                model.method
            );
        });
    }
    parallel::set_threads(0);
}

#[test]
fn knn_predict_thread_count_invariant() {
    let _g = lock();
    let train = gaussian_mixture_2d(300, 3, 0.3, 10);
    let test = gaussian_mixture_2d(120, 3, 0.3, 11);
    let knn = KnnClassifier::fit(train.x.clone(), train.y.clone(), 3);
    parallel::set_threads(1);
    let reference = knn.predict(&test.x);
    for_thread_counts(|t| {
        assert_eq!(knn.predict(&test.x), reference, "knn t={t}");
    });
}

#[test]
fn mmd_sums_within_reassociation_tolerance() {
    let _g = lock();
    let x = gaussian_mixture_2d(220, 3, 0.4, 12).x;
    let kernel = Kernel::gaussian(1.0);
    let rs = ShadowDensity::new(4.0).reduce(&x, &kernel);
    parallel::set_threads(1);
    let reference = mmd_weighted(&x, &rs.centers, &rs.weights, &kernel);
    for_thread_counts(|t| {
        let v = mmd_weighted(&x, &rs.centers, &rs.weights, &kernel);
        assert!(
            (v - reference).abs() <= 1e-10,
            "mmd t={t}: {v} vs {reference}"
        );
    });
}

#[test]
fn fits_are_deterministic_under_fixed_seeds_at_8_threads() {
    let _g = lock();
    parallel::set_threads(8);
    let ds = gaussian_mixture_2d(180, 3, 0.35, 13);
    let kernel = Kernel::gaussian(1.2);
    let rs1 = ShadowDensity::new(4.0).reduce(&ds.x, &kernel);
    let rs2 = ShadowDensity::new(4.0).reduce(&ds.x, &kernel);
    assert_eq!(rs1.weights, rs2.weights);
    let m1 = fit_rskpca(&rs1, &kernel, 4).unwrap();
    let m2 = fit_rskpca(&rs2, &kernel, 4).unwrap();
    assert_eq!(m1.coeffs.as_slice(), m2.coeffs.as_slice());
    assert_eq!(m1.op_eigenvalues, m2.op_eigenvalues);
    let n1 = fit_nystrom(&ds.x, &kernel, 3, 25, 77).unwrap();
    let n2 = fit_nystrom(&ds.x, &kernel, 3, 25, 77).unwrap();
    assert_eq!(n1.coeffs.as_slice(), n2.coeffs.as_slice());
    let z1 = m1.transform_batch(&ds.x);
    let z2 = m2.transform_batch(&ds.x);
    assert_eq!(z1.as_slice(), z2.as_slice());
    parallel::set_threads(0);
}
