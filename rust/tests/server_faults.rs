//! Fault-injection suite for the event-driven serving core: hostile
//! and unlucky clients — slow-loris header drips, mid-body
//! disconnects, never-reading response sinks, keep-alive churn, and
//! an idle-connection soak — each paired with the invariant that a
//! healthy probe keeps answering within a deadline.  The scenarios
//! run at event-thread counts {1, 2, 8}; the single-thread runs are
//! the sharpest: with one event thread, any scenario that blocked a
//! thread (as each of these did under the old thread-per-connection
//! pool) would stall the probe outright.
//!
//! The chaos half of the suite injects faults *behind* the HTTP
//! layer: a backend that panics on its Nth Gram call (the panicked
//! batch answers 500, everything after keeps answering 200), expired
//! request deadlines (shed with 504 before any GEMM runs), and a
//! corrupted model file on the swap path (detected by the checksum
//! trailer, quarantined, never served).

use std::fmt::Write as _;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use rskpca::config::{ServerConfig, ServiceConfig};
use rskpca::coordinator::EmbeddingService;
use rskpca::data::gaussian_mixture_2d;
use rskpca::kernel::Kernel;
use rskpca::kpca::{fit_kpca, EmbeddingModel};
use rskpca::linalg::Matrix;
use rskpca::obs::prom;
use rskpca::runtime::{BackendFactory, GramBackend, NativeBackend};
use rskpca::server::http::ClientConn;
use rskpca::server::HttpServer;

const CONNECT: Duration = Duration::from_millis(2000);

/// Deadline for a healthy probe while a fault scenario is in flight.
const PROBE_DEADLINE: Duration = Duration::from_millis(2000);

fn test_model() -> EmbeddingModel {
    let ds = gaussian_mixture_2d(80, 3, 0.4, 1);
    fit_kpca(&ds.x, &Kernel::gaussian(1.0), 4).unwrap()
}

fn native() -> BackendFactory {
    Box::new(|| Ok(Box::new(NativeBackend::new())))
}

/// A backend whose `panic_on`-th Gram call panics (then never again —
/// the shared counter keeps climbing past the trigger).  `embed` and
/// `embed_model` ride the default trait implementations, so every
/// served batch routes through exactly one counted `gram` call.  Note
/// the worker's startup warmup is call #1.
struct PanicOnNthGram {
    calls: Arc<AtomicUsize>,
    panic_on: usize,
    inner: NativeBackend,
}

impl GramBackend for PanicOnNthGram {
    fn gram(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        kernel: &Kernel,
    ) -> rskpca::error::Result<Matrix> {
        let n = self.calls.fetch_add(1, Ordering::SeqCst) + 1;
        if n == self.panic_on {
            panic!("injected backend panic (gram call {n})");
        }
        self.inner.gram(x, y, kernel)
    }

    fn name(&self) -> &'static str {
        "panic-nth"
    }
}

fn panicking(calls: Arc<AtomicUsize>, panic_on: usize) -> BackendFactory {
    Box::new(move || {
        Ok(Box::new(PanicOnNthGram {
            calls: calls.clone(),
            panic_on,
            inner: NativeBackend::new(),
        }) as Box<dyn GramBackend>)
    })
}

/// Spawn service + front end with full control over the backend and
/// both config layers (`listen`/`workers`/`keep_alive_ms` are forced).
fn start_custom(
    workers: usize,
    keep_alive_ms: u64,
    factory: BackendFactory,
    svc_cfg: ServiceConfig,
    mut server_cfg: ServerConfig,
) -> (EmbeddingService, HttpServer, String) {
    let svc =
        EmbeddingService::start(test_model(), factory, svc_cfg).unwrap();
    server_cfg.listen = "127.0.0.1:0".into();
    server_cfg.workers = workers;
    server_cfg.keep_alive_ms = keep_alive_ms;
    let server = HttpServer::start(svc.handle(), &server_cfg).unwrap();
    let target = server.local_addr().to_string();
    (svc, server, target)
}

/// Spawn service + front end with `workers` event threads and the
/// given idle timeout.
fn start(
    workers: usize,
    keep_alive_ms: u64,
) -> (EmbeddingService, HttpServer, String) {
    start_custom(
        workers,
        keep_alive_ms,
        native(),
        ServiceConfig::default(),
        ServerConfig::default(),
    )
}

/// Scrape `GET /metrics` (strictly parsed) and read one series.
fn metric(target: &str, name: &str) -> f64 {
    let mut conn = ClientConn::connect(target, CONNECT).unwrap();
    let resp = conn.request("GET", "/metrics", b"").unwrap();
    assert_eq!(resp.status, 200);
    let text = std::str::from_utf8(&resp.body).unwrap();
    let parsed = prom::parse(text).unwrap();
    parsed.value(name).unwrap_or(0.0)
}

/// Assert `GET /healthz` answers 200 within [`PROBE_DEADLINE`].
fn assert_probe_healthy(target: &str) {
    let t0 = Instant::now();
    let mut conn = ClientConn::connect(target, CONNECT).unwrap();
    let resp = conn.request("GET", "/healthz", b"").unwrap();
    assert_eq!(resp.status, 200);
    assert!(
        t0.elapsed() < PROBE_DEADLINE,
        "healthz took {:?}",
        t0.elapsed()
    );
}

/// Read `http.conns_open` from `GET /stats`.
fn conns_open(target: &str) -> f64 {
    let mut conn = ClientConn::connect(target, CONNECT).unwrap();
    let resp = conn.request("GET", "/stats", b"").unwrap();
    assert_eq!(resp.status, 200);
    resp.json()
        .unwrap()
        .req("http")
        .unwrap()
        .req_f64("conns_open")
        .unwrap()
}

/// A `{"rows": [[...]...]}` embed body with `rows` two-feature rows.
fn embed_body(rows: usize) -> String {
    let mut s = String::from("{\"rows\":[");
    for i in 0..rows {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "[{}.0,{}.5]", i % 7, (i + 3) % 5);
    }
    s.push_str("]}");
    s
}

/// A slow-loris client dripping header bytes one at a time must not
/// delay other clients, and must be reaped once it makes no complete
/// request for `keep_alive_ms` — partial reads do not count as
/// progress.
#[test]
fn slow_loris_drip_is_contained_and_reaped() {
    for workers in [1usize, 2, 8] {
        let (svc, server, target) = start(workers, 400);
        let loris_target = target.clone();
        let loris = std::thread::spawn(move || {
            let mut s = TcpStream::connect(&loris_target).unwrap();
            let head = b"GET /healthz HTTP/1.1\r\nhost: x\r\n\r\n";
            for &b in head.iter() {
                if s.write_all(&[b]).is_err() {
                    return true; // server closed us mid-drip
                }
                let _ = s.flush();
                std::thread::sleep(Duration::from_millis(50));
            }
            // The full drip takes ~1.8 s against a 400 ms idle
            // timeout, so the write loop should have hit a closed
            // socket; if every byte was accepted, the final read must
            // see EOF/reset rather than a response.
            s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            let mut buf = [0u8; 256];
            !matches!(s.read(&mut buf), Ok(n) if n > 0)
        });
        // While the drip is in flight, healthy traffic flows — even
        // with a single event thread.
        for _ in 0..5 {
            assert_probe_healthy(&target);
            std::thread::sleep(Duration::from_millis(100));
        }
        assert!(
            loris.join().unwrap(),
            "slow-loris connection survived the idle timeout \
             (workers={workers})"
        );
        // The reap is observable: the idle sweep left a structured
        // `http.conn.reaped` event in the service's event ring.
        let reaped = svc.handle().obs().events_named("http.conn.reaped");
        assert!(
            !reaped.is_empty(),
            "no http.conn.reaped event for the loris \
             (workers={workers})"
        );
        assert!(reaped.iter().all(|e| e.prop("idle_ms").is_some()));
        server.shutdown();
        svc.shutdown();
    }
}

/// A client that declares a body and disconnects halfway through
/// leaves no residue: the probe stays healthy and the connection
/// count returns to just the observer's.
#[test]
fn mid_body_disconnect_leaves_server_healthy() {
    for workers in [1usize, 2, 8] {
        let (svc, server, target) = start(workers, 400);
        for _ in 0..8 {
            let mut s = TcpStream::connect(&target).unwrap();
            s.write_all(
                b"POST /embed HTTP/1.1\r\nhost: x\r\n\
                  content-type: application/json\r\n\
                  content-length: 4000\r\n\r\n{\"rows\":[[1.0",
            )
            .unwrap();
            drop(s); // vanish mid-body
        }
        assert_probe_healthy(&target);
        // The half-fed connections hit EOF and are dropped without
        // waiting for the idle timer.
        let deadline = Instant::now() + Duration::from_secs(3);
        loop {
            if conns_open(&target) <= 2.0 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "mid-body disconnects were not cleaned up \
                 (workers={workers})"
            );
            std::thread::sleep(Duration::from_millis(50));
        }
        // Each vanished client surfaced as an `http.conn.eof` event.
        assert!(
            !svc.handle().obs().events_named("http.conn.eof").is_empty(),
            "no http.conn.eof events after mid-body disconnects \
             (workers={workers})"
        );
        server.shutdown();
        svc.shutdown();
    }
}

/// A client that submits work and never reads the response exerts
/// write backpressure; it must cost one connection slot (reaped on
/// the idle timer), never a thread.
#[test]
fn never_reading_client_is_absorbed_and_reaped() {
    for workers in [1usize, 2] {
        let (svc, server, target) = start(workers, 400);
        // Large-ish embeds so the responses materially exceed one
        // socket write.
        let body = embed_body(512);
        let mut sinks = Vec::new();
        for _ in 0..4 {
            let mut s = TcpStream::connect(&target).unwrap();
            let mut req = String::new();
            let _ = write!(
                req,
                "POST /embed HTTP/1.1\r\nhost: x\r\n\
                 content-type: application/json\r\n\
                 content-length: {}\r\n\r\n{body}",
                body.len()
            );
            s.write_all(req.as_bytes()).unwrap();
            sinks.push(s); // never read from it
        }
        for _ in 0..5 {
            assert_probe_healthy(&target);
            std::thread::sleep(Duration::from_millis(100));
        }
        // Idle timer must clear the sinks (response written or
        // stalled — either way, no further progress happened).
        let deadline = Instant::now() + Duration::from_secs(4);
        loop {
            if conns_open(&target) <= 2.0 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "never-reading clients were not reaped \
                 (workers={workers})"
            );
            std::thread::sleep(Duration::from_millis(50));
        }
        drop(sinks);
        server.shutdown();
        svc.shutdown();
    }
}

/// Regression for the idle keep-alive timeout: a connection that goes
/// silent right after connecting is closed within `keep_alive_ms`
/// (plus scheduling slack) — it does not linger for the life of the
/// server.
#[test]
fn connect_and_go_silent_is_reaped_within_keep_alive() {
    let (svc, server, target) = start(2, 300);
    let mut silent = TcpStream::connect(&target).unwrap();
    silent
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    let t0 = Instant::now();
    // A blocking read observes the server-initiated close (EOF or
    // reset) without us ever sending a byte.
    let mut buf = [0u8; 16];
    let closed = match silent.read(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => {
            e.kind() != ErrorKind::WouldBlock
                && e.kind() != ErrorKind::TimedOut
        }
    };
    assert!(closed, "silent connection was never closed");
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_secs(3),
        "reap took {waited:?} against a 300 ms idle timeout"
    );
    assert_probe_healthy(&target);
    server.shutdown();
    svc.shutdown();
}

/// Rapid connect / request / disconnect churn: every request answers
/// 200 and the server ends clean.
#[test]
fn keep_alive_churn_serves_every_request() {
    let (svc, server, target) = start(2, 1000);
    let body = embed_body(3);
    for _ in 0..100 {
        let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
        let resp = conn
            .request("POST", "/embed", body.as_bytes())
            .unwrap();
        assert_eq!(resp.status, 200);
        drop(conn); // churn: a fresh connection every request
    }
    assert_probe_healthy(&target);
    server.shutdown();
    let snap = svc.shutdown();
    assert_eq!(snap.requests, 100);
}

/// Soak: ~1000 idle connections held open simultaneously.  The server
/// must keep serving within the probe deadline while they sit there,
/// then reap them all on the idle timer.
#[test]
fn thousand_idle_connections_soak() {
    let (svc, server, target) = start(2, 1500);
    let mut idle = Vec::with_capacity(1000);
    for i in 0..1000 {
        match TcpStream::connect(&target) {
            Ok(s) => idle.push(s),
            Err(e) => panic!("connect #{i} failed: {e}"),
        }
        if i % 100 == 99 {
            // Brief pacing so the accept queue never overflows.
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    assert_probe_healthy(&target);
    let open = conns_open(&target);
    assert!(
        open >= 900.0,
        "expected ~1000 open connections, stats says {open}"
    );
    assert_probe_healthy(&target);
    // All of them go away once the idle timer fires.
    let deadline = Instant::now() + Duration::from_secs(8);
    loop {
        if conns_open(&target) <= 4.0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "idle soak connections were not reaped"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    drop(idle);
    server.shutdown();
    svc.shutdown();
}

/// Release-gated saturation check (debug builds are too slow for a
/// meaningful latency distribution): a 1000-connection closed-loop
/// burst produces zero malformed responses and a p99 within 2x p50 —
/// the deadline batcher keeps the tail close to the median because
/// every admitted request waits at most `max_wait_us` beyond its
/// batch.
#[cfg(not(debug_assertions))]
#[test]
fn saturation_tail_latency_release_gate() {
    use rskpca::server::loadgen::{self, LoadgenConfig};

    let (svc, server, target) = start(4, 5000);
    let mut report = loadgen::run(&LoadgenConfig {
        target,
        clients: 1000,
        requests_per_client: 3,
        rows_per_request: 4,
        dim: 0,
        seed: 0xFA57,
        warmup_ms: 5000,
        rate: 0.0,
        metrics_poll_s: 0,
        retry: false,
    })
    .unwrap();
    assert_eq!(
        report.errors, 0,
        "malformed/failed responses under saturation"
    );
    assert!(report.requests_ok > 0);
    let (p50, p99) = (report.p50_us(), report.p99_us());
    assert!(
        p99 <= 2.0 * p50,
        "tail blew past the batcher bound: p50={p50:.0}us \
         p99={p99:.0}us"
    );
    server.shutdown();
    svc.shutdown();
}

/// Chaos: the backend panics mid-run.  The panicked batch answers 500
/// to its own clients; every request after it answers 200 (the worker
/// rebuilds its backend and keeps going), the panic and restart are
/// visible in `/metrics`, and the probe never degrades.  Run at event
/// thread counts {1, 2, 8}.
#[test]
fn backend_panic_is_isolated_and_server_keeps_answering() {
    // Acceptance-scale subsequent traffic in release; debug builds run
    // a shorter tail so tier-1 stays fast.
    let subsequent =
        if cfg!(debug_assertions) { 300usize } else { 1000 };
    for workers in [1usize, 2, 8] {
        let calls = Arc::new(AtomicUsize::new(0));
        // Warmup is gram call #1, so the panic lands on the 2nd
        // served request.
        let (svc, server, target) = start_custom(
            workers,
            5000,
            panicking(calls.clone(), 3),
            ServiceConfig::default(),
            ServerConfig::default(),
        );
        let body = embed_body(3);
        let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
        let mut statuses = Vec::new();
        for _ in 0..(2 + subsequent) {
            let resp = conn
                .request("POST", "/embed", body.as_bytes())
                .unwrap();
            statuses.push(resp.status);
        }
        assert_eq!(
            statuses[0], 200,
            "pre-panic request must succeed (workers={workers})"
        );
        assert_eq!(
            statuses[1], 500,
            "the panicked batch answers 500 to its own requests \
             (workers={workers})"
        );
        assert!(
            statuses[2..].iter().all(|&s| s == 200),
            "a request after the panic did not answer 200 \
             (workers={workers})"
        );
        assert_probe_healthy(&target);
        // The panic and the backend rebuild are observable.
        assert!(
            metric(&target, "rskpca_worker_panics_total") >= 1.0,
            "panic counter missing from /metrics (workers={workers})"
        );
        assert!(
            metric(&target, "rskpca_worker_restarts_total") >= 1.0,
            "restart counter missing from /metrics (workers={workers})"
        );
        let obs = svc.handle().obs();
        assert_eq!(obs.events_named("worker.panic").len(), 1);
        assert_eq!(obs.events_named("worker.restart").len(), 1);
        server.shutdown();
        svc.shutdown();
    }
}

/// Chaos under concurrency: clients sharing batches with a poisoned
/// request all get a definite answer — 500 for the co-batched victims,
/// 200 for everyone else, and never a malformed or dropped response.
#[test]
fn co_batched_requests_all_complete_when_one_batch_panics() {
    let calls = Arc::new(AtomicUsize::new(0));
    let svc_cfg = ServiceConfig {
        max_batch: 8,
        max_wait_us: 2000,
        ..Default::default()
    };
    let (svc, server, target) = start_custom(
        2,
        5000,
        panicking(calls.clone(), 10),
        svc_cfg,
        ServerConfig::default(),
    );
    let mut threads = Vec::new();
    for _ in 0..4 {
        let target = target.clone();
        threads.push(std::thread::spawn(move || {
            let body = embed_body(2);
            let mut statuses = Vec::with_capacity(25);
            for _ in 0..25 {
                let mut conn =
                    ClientConn::connect(&target, CONNECT).unwrap();
                let resp = conn
                    .request("POST", "/embed", body.as_bytes())
                    .unwrap();
                statuses.push(resp.status);
            }
            statuses
        }));
    }
    let mut statuses = Vec::new();
    for t in threads {
        statuses.extend(t.join().unwrap());
    }
    assert_eq!(statuses.len(), 100, "every request got an answer");
    assert!(
        statuses.iter().all(|&s| s == 200 || s == 500),
        "unexpected statuses: {statuses:?}"
    );
    let failed = statuses.iter().filter(|&&s| s == 500).count();
    assert!(
        (1..=8).contains(&failed),
        "exactly one batch (1..=max_batch requests) fails, got {failed}"
    );
    assert_eq!(svc.handle().obs().hub.worker_panics(), 1);
    assert_probe_healthy(&target);
    server.shutdown();
    svc.shutdown();
}

/// Chaos: a request whose deadline already expired (`X-Deadline-Ms:
/// 0`) is shed at batch pickup — 504 to the client, the deadline-shed
/// counter ticks, and the GEMM stage histogram records nothing (the
/// work truly never reached compute).
#[test]
fn expired_deadline_is_shed_with_504_before_compute() {
    let (svc, server, target) = start(1, 5000);
    let body = embed_body(3);
    let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
    // Warm request: gives the GEMM histogram a baseline count.
    let ok = conn.request("POST", "/embed", body.as_bytes()).unwrap();
    assert_eq!(ok.status, 200);
    let gemm_before = metric(&target, "rskpca_gemm_us_count");
    let shed = conn
        .request_with_headers(
            "POST",
            "/embed",
            &[("x-deadline-ms", "0")],
            body.as_bytes(),
        )
        .unwrap();
    assert_eq!(shed.status, 504, "expired deadline must answer 504");
    assert_eq!(
        metric(&target, "rskpca_gemm_us_count"),
        gemm_before,
        "shed work must never reach the GEMM stage"
    );
    assert_eq!(metric(&target, "rskpca_deadline_shed_total"), 1.0);
    assert_eq!(
        svc.handle().obs().events_named("embed.expired").len(),
        1
    );
    // A generous deadline embeds normally.
    let fine = conn
        .request_with_headers(
            "POST",
            "/embed",
            &[("x-deadline-ms", "30000")],
            body.as_bytes(),
        )
        .unwrap();
    assert_eq!(fine.status, 200);
    server.shutdown();
    svc.shutdown();
}

/// Chaos: a model file corrupted on disk is caught by the v4 checksum
/// trailer at swap time — the swap is refused, the file is quarantined
/// as `.corrupt`, the serving model keeps answering, and the corruption
/// is visible in `/metrics`.  Pristine v4 and legacy trailerless files
/// still swap in fine.
#[test]
fn corrupt_model_file_is_quarantined_and_never_served() {
    let dir = std::env::temp_dir()
        .join(format!("rskpca_faults_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let server_cfg =
        ServerConfig { allow_path_swap: true, ..Default::default() };
    let (svc, server, target) = start_custom(
        2,
        5000,
        native(),
        ServiceConfig::default(),
        server_cfg,
    );

    // Corrupt a saved model by one byte inside the payload.
    let path = dir.join("swap.rskpca");
    test_model().save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, text.replacen("kernel", "kernal", 1)).unwrap();
    let swap_body = format!(
        "{{\"path\": {:?}}}",
        path.to_str().unwrap()
    );
    let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
    let resp = conn
        .request("POST", "/models/swap", swap_body.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 400, "corrupt model must be refused");
    assert!(
        std::str::from_utf8(&resp.body).unwrap().contains("checksum"),
        "refusal names the checksum failure"
    );
    assert!(!path.exists(), "corrupt file must be moved aside");
    let quarantined = dir.join("swap.rskpca.corrupt");
    assert!(quarantined.exists(), "quarantine file must exist");
    assert_eq!(metric(&target, "rskpca_model_corrupt_total"), 1.0);

    // The old model never stopped serving.
    let body = embed_body(3);
    let ok = conn.request("POST", "/embed", body.as_bytes()).unwrap();
    assert_eq!(ok.status, 200);
    assert_probe_healthy(&target);

    // A pristine v4 file swaps in...
    let good = dir.join("good.rskpca");
    test_model().save(&good).unwrap();
    let swap_good =
        format!("{{\"path\": {:?}}}", good.to_str().unwrap());
    let resp = conn
        .request("POST", "/models/swap", swap_good.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 200);
    // ...and so does a legacy trailerless document (pre-v4 files carry
    // no checksum and must remain loadable).
    let legacy = dir.join("legacy.rskpca");
    std::fs::write(&legacy, test_model().to_json().to_string())
        .unwrap();
    let swap_legacy =
        format!("{{\"path\": {:?}}}", legacy.to_str().unwrap());
    let resp = conn
        .request("POST", "/models/swap", swap_legacy.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 200);
    let ok = conn.request("POST", "/embed", body.as_bytes()).unwrap();
    assert_eq!(ok.status, 200);

    server.shutdown();
    svc.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// `/healthz` mirrors the refresh circuit breaker: an open or
/// half-open breaker reports "degraded" (still HTTP 200 — the serving
/// path is fine, the model is just stale), and closing it restores
/// "ok".
#[test]
fn healthz_reports_breaker_degradation_and_recovery() {
    let (svc, server, target) = start(1, 5000);
    let obs = svc.handle().obs();
    let hub = &obs.hub;
    let probe = |expect: &str| {
        let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
        let resp = conn.request("GET", "/healthz", b"").unwrap();
        assert_eq!(resp.status, 200);
        let v = resp.json().unwrap();
        assert_eq!(v.req_str("status").unwrap(), expect);
        v.req_str("refresh_breaker").unwrap().to_string()
    };
    assert_eq!(probe("ok"), "closed");
    hub.set_breaker_state(1);
    assert_eq!(probe("degraded"), "open");
    hub.set_breaker_state(2);
    assert_eq!(probe("degraded"), "half-open");
    hub.set_breaker_state(0);
    assert_eq!(probe("ok"), "closed");
    server.shutdown();
    svc.shutdown();
}
