//! Fault-injection suite for the event-driven serving core: hostile
//! and unlucky clients — slow-loris header drips, mid-body
//! disconnects, never-reading response sinks, keep-alive churn, and
//! an idle-connection soak — each paired with the invariant that a
//! healthy probe keeps answering within a deadline.  The scenarios
//! run at event-thread counts {1, 2, 8}; the single-thread runs are
//! the sharpest: with one event thread, any scenario that blocked a
//! thread (as each of these did under the old thread-per-connection
//! pool) would stall the probe outright.

use std::fmt::Write as _;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use rskpca::config::{ServerConfig, ServiceConfig};
use rskpca::coordinator::EmbeddingService;
use rskpca::data::gaussian_mixture_2d;
use rskpca::kernel::Kernel;
use rskpca::kpca::{fit_kpca, EmbeddingModel};
use rskpca::runtime::{BackendFactory, NativeBackend};
use rskpca::server::http::ClientConn;
use rskpca::server::HttpServer;

const CONNECT: Duration = Duration::from_millis(2000);

/// Deadline for a healthy probe while a fault scenario is in flight.
const PROBE_DEADLINE: Duration = Duration::from_millis(2000);

fn test_model() -> EmbeddingModel {
    let ds = gaussian_mixture_2d(80, 3, 0.4, 1);
    fit_kpca(&ds.x, &Kernel::gaussian(1.0), 4).unwrap()
}

fn native() -> BackendFactory {
    Box::new(|| Ok(Box::new(NativeBackend::new())))
}

/// Spawn service + front end with `workers` event threads and the
/// given idle timeout.
fn start(
    workers: usize,
    keep_alive_ms: u64,
) -> (EmbeddingService, HttpServer, String) {
    let svc = EmbeddingService::start(
        test_model(),
        native(),
        ServiceConfig::default(),
    )
    .unwrap();
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers,
        keep_alive_ms,
        ..Default::default()
    };
    let server = HttpServer::start(svc.handle(), &cfg).unwrap();
    let target = server.local_addr().to_string();
    (svc, server, target)
}

/// Assert `GET /healthz` answers 200 within [`PROBE_DEADLINE`].
fn assert_probe_healthy(target: &str) {
    let t0 = Instant::now();
    let mut conn = ClientConn::connect(target, CONNECT).unwrap();
    let resp = conn.request("GET", "/healthz", b"").unwrap();
    assert_eq!(resp.status, 200);
    assert!(
        t0.elapsed() < PROBE_DEADLINE,
        "healthz took {:?}",
        t0.elapsed()
    );
}

/// Read `http.conns_open` from `GET /stats`.
fn conns_open(target: &str) -> f64 {
    let mut conn = ClientConn::connect(target, CONNECT).unwrap();
    let resp = conn.request("GET", "/stats", b"").unwrap();
    assert_eq!(resp.status, 200);
    resp.json()
        .unwrap()
        .req("http")
        .unwrap()
        .req_f64("conns_open")
        .unwrap()
}

/// A `{"rows": [[...]...]}` embed body with `rows` two-feature rows.
fn embed_body(rows: usize) -> String {
    let mut s = String::from("{\"rows\":[");
    for i in 0..rows {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "[{}.0,{}.5]", i % 7, (i + 3) % 5);
    }
    s.push_str("]}");
    s
}

/// A slow-loris client dripping header bytes one at a time must not
/// delay other clients, and must be reaped once it makes no complete
/// request for `keep_alive_ms` — partial reads do not count as
/// progress.
#[test]
fn slow_loris_drip_is_contained_and_reaped() {
    for workers in [1usize, 2, 8] {
        let (svc, server, target) = start(workers, 400);
        let loris_target = target.clone();
        let loris = std::thread::spawn(move || {
            let mut s = TcpStream::connect(&loris_target).unwrap();
            let head = b"GET /healthz HTTP/1.1\r\nhost: x\r\n\r\n";
            for &b in head.iter() {
                if s.write_all(&[b]).is_err() {
                    return true; // server closed us mid-drip
                }
                let _ = s.flush();
                std::thread::sleep(Duration::from_millis(50));
            }
            // The full drip takes ~1.8 s against a 400 ms idle
            // timeout, so the write loop should have hit a closed
            // socket; if every byte was accepted, the final read must
            // see EOF/reset rather than a response.
            s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            let mut buf = [0u8; 256];
            !matches!(s.read(&mut buf), Ok(n) if n > 0)
        });
        // While the drip is in flight, healthy traffic flows — even
        // with a single event thread.
        for _ in 0..5 {
            assert_probe_healthy(&target);
            std::thread::sleep(Duration::from_millis(100));
        }
        assert!(
            loris.join().unwrap(),
            "slow-loris connection survived the idle timeout \
             (workers={workers})"
        );
        // The reap is observable: the idle sweep left a structured
        // `http.conn.reaped` event in the service's event ring.
        let reaped = svc.handle().obs().events_named("http.conn.reaped");
        assert!(
            !reaped.is_empty(),
            "no http.conn.reaped event for the loris \
             (workers={workers})"
        );
        assert!(reaped.iter().all(|e| e.prop("idle_ms").is_some()));
        server.shutdown();
        svc.shutdown();
    }
}

/// A client that declares a body and disconnects halfway through
/// leaves no residue: the probe stays healthy and the connection
/// count returns to just the observer's.
#[test]
fn mid_body_disconnect_leaves_server_healthy() {
    for workers in [1usize, 2, 8] {
        let (svc, server, target) = start(workers, 400);
        for _ in 0..8 {
            let mut s = TcpStream::connect(&target).unwrap();
            s.write_all(
                b"POST /embed HTTP/1.1\r\nhost: x\r\n\
                  content-type: application/json\r\n\
                  content-length: 4000\r\n\r\n{\"rows\":[[1.0",
            )
            .unwrap();
            drop(s); // vanish mid-body
        }
        assert_probe_healthy(&target);
        // The half-fed connections hit EOF and are dropped without
        // waiting for the idle timer.
        let deadline = Instant::now() + Duration::from_secs(3);
        loop {
            if conns_open(&target) <= 2.0 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "mid-body disconnects were not cleaned up \
                 (workers={workers})"
            );
            std::thread::sleep(Duration::from_millis(50));
        }
        // Each vanished client surfaced as an `http.conn.eof` event.
        assert!(
            !svc.handle().obs().events_named("http.conn.eof").is_empty(),
            "no http.conn.eof events after mid-body disconnects \
             (workers={workers})"
        );
        server.shutdown();
        svc.shutdown();
    }
}

/// A client that submits work and never reads the response exerts
/// write backpressure; it must cost one connection slot (reaped on
/// the idle timer), never a thread.
#[test]
fn never_reading_client_is_absorbed_and_reaped() {
    for workers in [1usize, 2] {
        let (svc, server, target) = start(workers, 400);
        // Large-ish embeds so the responses materially exceed one
        // socket write.
        let body = embed_body(512);
        let mut sinks = Vec::new();
        for _ in 0..4 {
            let mut s = TcpStream::connect(&target).unwrap();
            let mut req = String::new();
            let _ = write!(
                req,
                "POST /embed HTTP/1.1\r\nhost: x\r\n\
                 content-type: application/json\r\n\
                 content-length: {}\r\n\r\n{body}",
                body.len()
            );
            s.write_all(req.as_bytes()).unwrap();
            sinks.push(s); // never read from it
        }
        for _ in 0..5 {
            assert_probe_healthy(&target);
            std::thread::sleep(Duration::from_millis(100));
        }
        // Idle timer must clear the sinks (response written or
        // stalled — either way, no further progress happened).
        let deadline = Instant::now() + Duration::from_secs(4);
        loop {
            if conns_open(&target) <= 2.0 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "never-reading clients were not reaped \
                 (workers={workers})"
            );
            std::thread::sleep(Duration::from_millis(50));
        }
        drop(sinks);
        server.shutdown();
        svc.shutdown();
    }
}

/// Regression for the idle keep-alive timeout: a connection that goes
/// silent right after connecting is closed within `keep_alive_ms`
/// (plus scheduling slack) — it does not linger for the life of the
/// server.
#[test]
fn connect_and_go_silent_is_reaped_within_keep_alive() {
    let (svc, server, target) = start(2, 300);
    let mut silent = TcpStream::connect(&target).unwrap();
    silent
        .set_read_timeout(Some(Duration::from_secs(5)))
        .unwrap();
    let t0 = Instant::now();
    // A blocking read observes the server-initiated close (EOF or
    // reset) without us ever sending a byte.
    let mut buf = [0u8; 16];
    let closed = match silent.read(&mut buf) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => {
            e.kind() != ErrorKind::WouldBlock
                && e.kind() != ErrorKind::TimedOut
        }
    };
    assert!(closed, "silent connection was never closed");
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_secs(3),
        "reap took {waited:?} against a 300 ms idle timeout"
    );
    assert_probe_healthy(&target);
    server.shutdown();
    svc.shutdown();
}

/// Rapid connect / request / disconnect churn: every request answers
/// 200 and the server ends clean.
#[test]
fn keep_alive_churn_serves_every_request() {
    let (svc, server, target) = start(2, 1000);
    let body = embed_body(3);
    for _ in 0..100 {
        let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
        let resp = conn
            .request("POST", "/embed", body.as_bytes())
            .unwrap();
        assert_eq!(resp.status, 200);
        drop(conn); // churn: a fresh connection every request
    }
    assert_probe_healthy(&target);
    server.shutdown();
    let snap = svc.shutdown();
    assert_eq!(snap.requests, 100);
}

/// Soak: ~1000 idle connections held open simultaneously.  The server
/// must keep serving within the probe deadline while they sit there,
/// then reap them all on the idle timer.
#[test]
fn thousand_idle_connections_soak() {
    let (svc, server, target) = start(2, 1500);
    let mut idle = Vec::with_capacity(1000);
    for i in 0..1000 {
        match TcpStream::connect(&target) {
            Ok(s) => idle.push(s),
            Err(e) => panic!("connect #{i} failed: {e}"),
        }
        if i % 100 == 99 {
            // Brief pacing so the accept queue never overflows.
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    assert_probe_healthy(&target);
    let open = conns_open(&target);
    assert!(
        open >= 900.0,
        "expected ~1000 open connections, stats says {open}"
    );
    assert_probe_healthy(&target);
    // All of them go away once the idle timer fires.
    let deadline = Instant::now() + Duration::from_secs(8);
    loop {
        if conns_open(&target) <= 4.0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "idle soak connections were not reaped"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    drop(idle);
    server.shutdown();
    svc.shutdown();
}

/// Release-gated saturation check (debug builds are too slow for a
/// meaningful latency distribution): a 1000-connection closed-loop
/// burst produces zero malformed responses and a p99 within 2x p50 —
/// the deadline batcher keeps the tail close to the median because
/// every admitted request waits at most `max_wait_us` beyond its
/// batch.
#[cfg(not(debug_assertions))]
#[test]
fn saturation_tail_latency_release_gate() {
    use rskpca::server::loadgen::{self, LoadgenConfig};

    let (svc, server, target) = start(4, 5000);
    let mut report = loadgen::run(&LoadgenConfig {
        target,
        clients: 1000,
        requests_per_client: 3,
        rows_per_request: 4,
        dim: 0,
        seed: 0xFA57,
        warmup_ms: 5000,
        rate: 0.0,
        metrics_poll_s: 0,
    })
    .unwrap();
    assert_eq!(
        report.errors, 0,
        "malformed/failed responses under saturation"
    );
    assert!(report.requests_ok > 0);
    let (p50, p99) = (report.p50_us(), report.p99_us());
    assert!(
        p99 <= 2.0 * p50,
        "tail blew past the batcher bound: p50={p50:.0}us \
         p99={p99:.0}us"
    );
    server.shutdown();
    svc.shutdown();
}
