//! Loopback integration tests for the HTTP serving subsystem: real TCP
//! on an ephemeral port, concurrent clients, a mid-traffic hot swap
//! over the wire, and 429-on-saturation semantics.

use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use rskpca::config::{QueuePolicy, ServerConfig, ServiceConfig};
use rskpca::coordinator::EmbeddingService;
use rskpca::data::gaussian_mixture_2d;
use rskpca::error::Result;
use rskpca::kernel::Kernel;
use rskpca::kpca::{fit_kpca, EmbeddingModel};
use rskpca::linalg::Matrix;
use rskpca::runtime::{BackendFactory, GramBackend, NativeBackend};
use rskpca::ser::Json;
use rskpca::server::http::ClientConn;
use rskpca::server::loadgen::{self, LoadgenConfig};
use rskpca::server::HttpServer;

const CONNECT: Duration = Duration::from_millis(2000);

fn test_model() -> (EmbeddingModel, Matrix) {
    let ds = gaussian_mixture_2d(80, 3, 0.4, 1);
    let k = Kernel::gaussian(1.0);
    let model = fit_kpca(&ds.x, &k, 4).unwrap();
    (model, ds.x)
}

fn native() -> BackendFactory {
    Box::new(|| Ok(Box::new(NativeBackend::new())))
}

fn server_cfg() -> ServerConfig {
    ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 8,
        ..Default::default()
    }
}

/// Spawn service + HTTP front end; returns both plus the target addr.
fn start(
    model: EmbeddingModel,
    svc_cfg: ServiceConfig,
    srv_cfg: &ServerConfig,
    factory: BackendFactory,
) -> (EmbeddingService, HttpServer, String) {
    let svc = EmbeddingService::start(model, factory, svc_cfg).unwrap();
    let server = HttpServer::start(svc.handle(), srv_cfg).unwrap();
    let target = server.local_addr().to_string();
    (svc, server, target)
}

/// Full-precision `{"rows": [...]}` body for selected rows of `x`.
fn rows_body(x: &Matrix, idx: &[usize]) -> String {
    let mut s = String::from("{\"rows\":[");
    for (n, &i) in idx.iter().enumerate() {
        if n > 0 {
            s.push(',');
        }
        s.push('[');
        for j in 0..x.cols() {
            if j > 0 {
                s.push(',');
            }
            let _ = write!(s, "{}", x.get(i, j));
        }
        s.push(']');
    }
    s.push_str("]}");
    s
}

/// Extract the `embedding` field of a 200 response body.
fn embedding_from(body: &[u8]) -> Matrix {
    let v = rskpca::ser::parse(std::str::from_utf8(body).unwrap())
        .unwrap();
    let rows = v.get("embedding").unwrap().as_arr().unwrap();
    let cols = rows[0].as_arr().unwrap().len();
    let mut m = Matrix::zeros(rows.len(), cols);
    for (i, row) in rows.iter().enumerate() {
        for (j, x) in row.as_arr().unwrap().iter().enumerate() {
            m.set(i, j, x.as_f64().unwrap());
        }
    }
    m
}

fn close_to(a: &Matrix, b: &Matrix, tol: f64) -> bool {
    a.rows() == b.rows()
        && a.cols() == b.cols()
        && a.sub(b).unwrap().max_abs() < tol
}

#[test]
fn healthz_models_stats_and_unknown_routes() {
    let (model, x) = test_model();
    let (svc, server, target) = start(
        model,
        ServiceConfig::default(),
        &server_cfg(),
        native(),
    );
    let mut conn = ClientConn::connect(&target, CONNECT).unwrap();

    let resp = conn.request("GET", "/healthz", b"").unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.json().unwrap().req_str("status").unwrap(), "ok");

    let resp = conn.request("GET", "/models", b"").unwrap();
    assert_eq!(resp.status, 200);
    let v = resp.json().unwrap();
    assert_eq!(v.req_str("serving").unwrap(), "default");
    let models = v.req("models").unwrap().as_arr().unwrap();
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].req_usize("dim").unwrap(), 2);
    assert_eq!(models[0].req_usize("version").unwrap(), 1);

    // Drive one embed so /stats has service + route samples.
    let body = rows_body(&x, &[0, 1, 2]);
    let resp = conn
        .request("POST", "/embed", body.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 200);

    let resp = conn.request("GET", "/stats", b"").unwrap();
    assert_eq!(resp.status, 200);
    let v = resp.json().unwrap();
    let service = v.req("service").unwrap();
    assert_eq!(service.req_f64("requests").unwrap(), 1.0);
    assert_eq!(service.req_f64("rows").unwrap(), 3.0);
    assert!(service.req_f64("latency_p99_us").unwrap() > 0.0);
    let routes = v.req("routes").unwrap();
    let embed_route = routes.get("POST /embed").unwrap();
    assert_eq!(embed_route.req_f64("hits").unwrap(), 1.0);
    assert!(embed_route.req_f64("latency_p99_us").unwrap() > 0.0);

    // Unknown route and wrong method.
    assert_eq!(
        conn.request("GET", "/nope", b"").unwrap().status,
        404
    );
    assert_eq!(
        conn.request("DELETE", "/healthz", b"").unwrap().status,
        405
    );
    // Server-side path swaps are gated off by default (403) — only
    // inline models are accepted on an unauthenticated surface.
    let resp = conn
        .request(
            "POST",
            "/models/swap",
            br#"{"path": "/etc/hostname"}"#,
        )
        .unwrap();
    assert_eq!(resp.status, 403);
    drop(conn);
    server.shutdown();
    svc.shutdown();
}

#[test]
fn embed_over_http_matches_direct_transform() {
    let (model, x) = test_model();
    let expect = model.transform(&x);
    let (svc, server, target) = start(
        model,
        ServiceConfig::default(),
        &server_cfg(),
        native(),
    );
    let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
    let idx: Vec<usize> = (10..30).collect();
    let body = rows_body(&x, &idx);
    let resp = conn
        .request("POST", "/embed", body.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 200);
    let got = embedding_from(&resp.body);
    let want = expect.select_rows(&idx);
    assert!(close_to(&got, &want, 1e-9), "HTTP embed diverged");
    let v = resp.json().unwrap();
    assert_eq!(v.req_usize("rows").unwrap(), idx.len());
    assert_eq!(v.req_usize("rank").unwrap(), want.cols());
    drop(conn);
    server.shutdown();
    svc.shutdown();
}

#[test]
fn malformed_bodies_get_400_and_connection_survives() {
    let (model, x) = test_model();
    let (svc, server, target) = start(
        model,
        ServiceConfig::default(),
        &server_cfg(),
        native(),
    );
    let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
    for bad in [
        "this is not json",
        r#"{"rows": []}"#,
        r#"{"rows": [[1, 2], [3]]}"#,
        r#"{"rows": [[1, 2, 3]]}"#, // wrong feature dim -> shape error
        r#"{"wrong": 1}"#,
    ] {
        let resp = conn
            .request("POST", "/embed", bad.as_bytes())
            .unwrap();
        assert_eq!(resp.status, 400, "body {bad:?}");
    }
    // The same keep-alive connection still serves good requests.
    let body = rows_body(&x, &[0]);
    let resp = conn
        .request("POST", "/embed", body.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 200);
    drop(conn);
    server.shutdown();
    svc.shutdown();
}

#[test]
fn oversized_body_is_413_and_raw_bad_content_length_is_400() {
    let (model, x) = test_model();
    let mut cfg = server_cfg();
    cfg.max_body_bytes = 1024;
    let (svc, server, target) =
        start(model, ServiceConfig::default(), &cfg, native());

    // ~8 KiB body against a 1 KiB limit -> 413.
    let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
    let idx: Vec<usize> = (0..60).collect();
    let body = rows_body(&x, &idx);
    assert!(body.len() > 1024);
    let resp = conn
        .request("POST", "/embed", body.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 413);

    // Raw socket with a garbage content-length -> 400 and close.
    let mut raw = TcpStream::connect(&target).unwrap();
    raw.write_all(
        b"POST /embed HTTP/1.1\r\ncontent-length: banana\r\n\r\n",
    )
    .unwrap();
    let mut text = String::new();
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    raw.read_to_string(&mut text).unwrap();
    assert!(text.starts_with("HTTP/1.1 400"), "{text}");
    server.shutdown();
    svc.shutdown();
}

#[test]
fn concurrent_hammer_with_midtraffic_hot_swap() {
    let (model, x) = test_model();
    let expect_old = model.transform(&x);
    let doubled = EmbeddingModel {
        coeffs: model.coeffs.scale(2.0),
        ..model.clone()
    };
    let expect_new = expect_old.scale(2.0);
    let (svc, server, target) = start(
        model,
        ServiceConfig {
            max_batch: 64,
            max_wait_us: 300,
            ..Default::default()
        },
        &server_cfg(),
        native(),
    );

    let served_new = Arc::new(AtomicU64::new(0));
    let served_old = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for t in 0..4u64 {
        let target = target.clone();
        let x = x.clone();
        let expect_old = expect_old.clone();
        let expect_new = expect_new.clone();
        let served_new = served_new.clone();
        let served_old = served_old.clone();
        clients.push(std::thread::spawn(move || -> Result<()> {
            let mut conn = ClientConn::connect(&target, CONNECT)?;
            for round in 0..30u64 {
                // Pace the rounds so the mid-traffic swap reliably
                // lands while requests are still flowing.
                std::thread::sleep(Duration::from_millis(2));
                let start = ((t * 13 + round * 7) % 70) as usize;
                let idx: Vec<usize> = (start..start + 8).collect();
                let body = rows_body(&x, &idx);
                let resp =
                    conn.request("POST", "/embed", body.as_bytes())?;
                // Zero malformed responses allowed: every reply is a
                // parseable 200 matching exactly one model version.
                assert_eq!(resp.status, 200);
                let got = embedding_from(&resp.body);
                let want_old = expect_old.select_rows(&idx);
                let want_new = expect_new.select_rows(&idx);
                if close_to(&got, &want_old, 1e-9) {
                    served_old.fetch_add(1, Ordering::Relaxed);
                } else if close_to(&got, &want_new, 1e-9) {
                    served_new.fetch_add(1, Ordering::Relaxed);
                } else {
                    panic!(
                        "response matches neither model version \
                         (thread {t}, round {round})"
                    );
                }
            }
            Ok(())
        }));
    }

    // Mid-traffic: publish the doubled model over the wire (clients
    // pace at ~2 ms/round, so they are still mid-run here).
    std::thread::sleep(Duration::from_millis(20));
    let mut admin = ClientConn::connect(&target, CONNECT).unwrap();
    let swap_body = Json::obj()
        .with("model", doubled.to_json())
        .to_string();
    let resp = admin
        .request("POST", "/models/swap", swap_body.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 200);
    let v = resp.json().unwrap();
    assert_eq!(v.req_usize("version").unwrap(), 2);

    for c in clients {
        c.join().unwrap().unwrap();
    }
    // The swap happened mid-traffic: the new model must have served
    // at least one request, and nothing was malformed.
    assert!(
        served_new.load(Ordering::Relaxed) > 0,
        "hot swap never took effect"
    );
    assert_eq!(
        served_old.load(Ordering::Relaxed)
            + served_new.load(Ordering::Relaxed),
        120
    );

    // The registry reflects the swap.
    let resp = admin.request("GET", "/models", b"").unwrap();
    let v = resp.json().unwrap();
    let models = v.req("models").unwrap().as_arr().unwrap();
    assert_eq!(models[0].req_usize("version").unwrap(), 2);
    drop(admin);
    server.shutdown();
    svc.shutdown();
}

/// A backend that sleeps per batch — drives the queue into saturation.
struct SlowBackend {
    inner: NativeBackend,
    delay: Duration,
}

impl GramBackend for SlowBackend {
    fn gram(
        &mut self,
        x: &Matrix,
        y: &Matrix,
        kernel: &Kernel,
    ) -> Result<Matrix> {
        std::thread::sleep(self.delay);
        self.inner.gram(x, y, kernel)
    }
    fn name(&self) -> &'static str {
        "slow"
    }
}

#[test]
fn saturation_answers_429_with_retry_after() {
    let (model, x) = test_model();
    let mut cfg = server_cfg();
    cfg.retry_after_ms = 1500;
    let (svc, server, target) = start(
        model,
        ServiceConfig {
            max_batch: 1,
            max_wait_us: 1,
            queue_depth: 1,
            workers: 1,
        },
        &cfg,
        Box::new(|| {
            Ok(Box::new(SlowBackend {
                inner: NativeBackend::new(),
                delay: Duration::from_millis(30),
            }) as Box<dyn GramBackend>)
        }),
    );

    let ok = Arc::new(AtomicU64::new(0));
    let rejected = Arc::new(AtomicU64::new(0));
    let mut clients = Vec::new();
    for t in 0..8u64 {
        let target = target.clone();
        let x = x.clone();
        let ok = ok.clone();
        let rejected = rejected.clone();
        clients.push(std::thread::spawn(move || {
            let mut conn =
                ClientConn::connect(&target, CONNECT).unwrap();
            for round in 0..4u64 {
                let i = ((t * 4 + round) % 80) as usize;
                let body = rows_body(&x, &[i]);
                let resp = conn
                    .request("POST", "/embed", body.as_bytes())
                    .unwrap();
                match resp.status {
                    200 => {
                        ok.fetch_add(1, Ordering::Relaxed);
                    }
                    429 => {
                        rejected.fetch_add(1, Ordering::Relaxed);
                        // Admission control must carry the back-off
                        // hint (1500 ms rounds up to 2 s).
                        assert_eq!(
                            resp.header("retry-after"),
                            Some("2")
                        );
                        let v = resp.json().unwrap();
                        assert_eq!(
                            v.req_f64("retry_after_ms").unwrap(),
                            1500.0
                        );
                    }
                    other => panic!("unexpected status {other}"),
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    assert!(
        rejected.load(Ordering::Relaxed) > 0,
        "tiny queue never rejected under 8-way concurrency"
    );
    assert!(
        ok.load(Ordering::Relaxed) > 0,
        "saturated server should still serve some requests"
    );
    server.shutdown();
    let snap = svc.shutdown();
    assert_eq!(snap.rejected, rejected.load(Ordering::Relaxed));
}

#[test]
fn block_policy_waits_instead_of_rejecting() {
    let (model, x) = test_model();
    let mut cfg = server_cfg();
    cfg.queue_policy = QueuePolicy::Block;
    let (svc, server, target) = start(
        model,
        ServiceConfig {
            max_batch: 1,
            max_wait_us: 1,
            queue_depth: 1,
            workers: 1,
        },
        &cfg,
        Box::new(|| {
            Ok(Box::new(SlowBackend {
                inner: NativeBackend::new(),
                delay: Duration::from_millis(10),
            }) as Box<dyn GramBackend>)
        }),
    );
    let mut clients = Vec::new();
    for t in 0..4u64 {
        let target = target.clone();
        let x = x.clone();
        clients.push(std::thread::spawn(move || {
            let mut conn =
                ClientConn::connect(&target, CONNECT).unwrap();
            for round in 0..3u64 {
                let i = ((t * 3 + round) % 80) as usize;
                let body = rows_body(&x, &[i]);
                let resp = conn
                    .request("POST", "/embed", body.as_bytes())
                    .unwrap();
                assert_eq!(resp.status, 200, "block policy must wait");
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    server.shutdown();
    let snap = svc.shutdown();
    assert_eq!(snap.rejected, 0);
    assert_eq!(snap.requests, 12);
}

#[test]
fn loadgen_round_trip_reports_throughput() {
    let (model, _) = test_model();
    let (svc, server, target) = start(
        model,
        ServiceConfig::default(),
        &server_cfg(),
        native(),
    );
    let mut report = loadgen::run(&LoadgenConfig {
        target,
        clients: 3,
        requests_per_client: 10,
        rows_per_request: 4,
        dim: 0, // exercises GET /models discovery
        seed: 9,
        warmup_ms: 3000,
        rate: 0.0,
        metrics_poll_s: 1,
        retry: false,
    })
    .unwrap();
    assert_eq!(report.requests_ok, 30);
    assert_eq!(report.rows_ok, 120);
    assert_eq!(report.errors, 0);
    // The metrics poller always lands a final scrape on shutdown, so
    // even a sub-second run captures at least one parsed sample.
    assert_eq!(report.metrics_errors, 0);
    assert!(!report.metrics_samples.is_empty());
    assert!(report.metrics_samples.last().unwrap().requests_total >= 30.0);
    assert!(report.rows_per_s() > 0.0);
    assert!(report.latency_us.p99() > 0.0);
    let text = report.render();
    assert!(text.contains("30 ok"), "{text}");
    server.shutdown();
    svc.shutdown();
}
