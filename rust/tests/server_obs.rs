//! Loopback integration tests for the observability layer: the
//! Prometheus `/metrics` exposition is scraped over real TCP, strictly
//! parsed, and cross-checked against the JSON `/stats` snapshot; the
//! trace ids minted at accept time are verified to tie each
//! `http.request` event to its coordinator `span.embed`; and a
//! release-gated bound keeps the hot-path recording cost honest.

use std::collections::BTreeSet;
use std::fmt::Write as _;
use std::sync::Arc;
use std::time::{Duration, Instant};

use rskpca::config::{ObsConfig, ServerConfig, ServiceConfig};
use rskpca::coordinator::{
    serve_registry_obs, EmbeddingService, ModelRegistry, DEFAULT_MODEL,
};
use rskpca::data::gaussian_mixture_2d;
use rskpca::kernel::Kernel;
use rskpca::kpca::{fit_kpca, EmbeddingModel};
use rskpca::obs::prom;
use rskpca::obs::{Event, Obs};
use rskpca::runtime::{BackendFactory, NativeBackend};
use rskpca::server::http::ClientConn;
use rskpca::server::HttpServer;

const CONNECT: Duration = Duration::from_millis(2000);

fn test_model() -> EmbeddingModel {
    let ds = gaussian_mixture_2d(80, 3, 0.4, 1);
    fit_kpca(&ds.x, &Kernel::gaussian(1.0), 4).unwrap()
}

fn native() -> BackendFactory {
    Box::new(|| Ok(Box::new(NativeBackend::new())))
}

fn start() -> (EmbeddingService, HttpServer, String) {
    let svc = EmbeddingService::start(
        test_model(),
        native(),
        ServiceConfig::default(),
    )
    .unwrap();
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        ..Default::default()
    };
    let server = HttpServer::start(svc.handle(), &cfg).unwrap();
    let target = server.local_addr().to_string();
    (svc, server, target)
}

/// A `{"rows": [[...]...]}` embed body with `rows` two-feature rows.
fn embed_body(rows: usize) -> String {
    let mut s = String::from("{\"rows\":[");
    for i in 0..rows {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "[{}.0,{}.5]", i % 7, (i + 3) % 5);
    }
    s.push_str("]}");
    s
}

/// Scrape `GET /metrics` and run it through the strict parser.
fn scrape(conn: &mut ClientConn) -> prom::ParsedMetrics {
    let resp = conn.request("GET", "/metrics", b"").unwrap();
    assert_eq!(resp.status, 200);
    let text = std::str::from_utf8(&resp.body).unwrap();
    prom::parse(text).unwrap_or_else(|e| {
        panic!("exposition failed strict parse: {e}\n{text}")
    })
}

/// The `/metrics` document agrees with `/stats` on every counter the
/// embed path owns (those are stable between the two scrapes — only
/// the scrape requests themselves touch the other families).
#[test]
fn metrics_exposition_matches_stats_snapshot() {
    let (svc, server, target) = start();
    let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
    let body = embed_body(3);
    for _ in 0..12 {
        let resp = conn
            .request("POST", "/embed", body.as_bytes())
            .unwrap();
        assert_eq!(resp.status, 200);
    }

    let resp = conn.request("GET", "/stats", b"").unwrap();
    assert_eq!(resp.status, 200);
    let stats = resp.json().unwrap();
    let parsed = scrape(&mut conn);
    let value = |name: &str| {
        parsed
            .value(name)
            .unwrap_or_else(|| panic!("missing sample {name}"))
    };

    // Coordinator counters: /metrics and /stats took the same
    // snapshot source, so they must agree exactly.
    let service = stats.req("service").unwrap();
    assert_eq!(
        value("rskpca_requests_total"),
        service.req_f64("requests").unwrap()
    );
    assert_eq!(value("rskpca_requests_total"), 12.0);
    assert_eq!(value("rskpca_rows_total"), 36.0);
    assert_eq!(value("rskpca_rejected_total"), 0.0);
    assert_eq!(
        value("rskpca_batches_total"),
        service.req_f64("batches").unwrap()
    );
    assert_eq!(value("rskpca_model_version"), 1.0);

    // Stage histograms: one queue-wait sample per embed request, one
    // occupancy sample per batch, rows conserved across batches.
    assert_eq!(value("rskpca_queue_wait_us_count"), 12.0);
    assert_eq!(
        value("rskpca_queue_wait_us_count"),
        stats
            .req("stages")
            .unwrap()
            .req("queue_wait_us")
            .unwrap()
            .req_f64("count")
            .unwrap()
    );
    assert_eq!(
        value("rskpca_batch_rows_count"),
        value("rskpca_batches_total")
    );
    assert_eq!(
        value("rskpca_batch_rows_count"),
        stats
            .req("batch_occupancy")
            .unwrap()
            .req_f64("batches")
            .unwrap()
    );
    assert_eq!(
        value("rskpca_batch_rows_sum"),
        value("rskpca_rows_total")
    );
    // The response-write stage drained at least the twelve embeds.
    assert!(value("rskpca_write_us_count") >= 12.0);

    // Cumulative buckets: monotone, and +Inf equals the count.
    for stage in ["rskpca_queue_wait_us", "rskpca_batch_rows"] {
        let buckets = parsed.family(&format!("{stage}_bucket"));
        assert!(!buckets.is_empty(), "{stage} has no buckets");
        let mut prev = 0.0;
        for b in &buckets {
            assert!(
                b.value >= prev,
                "{stage} buckets not cumulative"
            );
            prev = b.value;
        }
        assert_eq!(buckets.last().unwrap().label("le"), Some("+Inf"));
        assert_eq!(prev, value(&format!("{stage}_count")));
    }

    // Route counters carry the full deterministic label set, with the
    // embed hits where they belong.
    let hits = parsed.family("rskpca_route_hits_total");
    assert_eq!(hits.len(), 7, "expected every route label");
    let embed_hits = hits
        .iter()
        .find(|s| s.label("route") == Some("POST /embed"))
        .unwrap();
    assert_eq!(embed_hits.value, 12.0);
    let stats_hits = hits
        .iter()
        .find(|s| s.label("route") == Some("GET /stats"))
        .unwrap();
    assert!(stats_hits.value >= 1.0);
    for s in parsed.family("rskpca_route_errors_total") {
        assert_eq!(s.value, 0.0, "unexpected route errors");
    }

    // Gauges and metadata.
    assert!(value("rskpca_http_conns_open") >= 1.0);
    assert!(value("rskpca_http_conns_accepted_total") >= 1.0);
    assert_eq!(value("rskpca_requests_1m"), 12.0);
    assert!(value("rskpca_uptime_seconds") > 0.0);
    assert_eq!(value("rskpca_obs_events_dropped_total"), 0.0);
    assert_eq!(
        parsed.types.get("rskpca_requests_total").map(String::as_str),
        Some("counter")
    );
    assert_eq!(
        parsed.types.get("rskpca_http_conns_open").map(String::as_str),
        Some("gauge")
    );
    assert_eq!(
        parsed.types.get("rskpca_queue_wait_us").map(String::as_str),
        Some("histogram")
    );

    server.shutdown();
    svc.shutdown();
}

/// Every embed answered over the wire leaves an `http.request` event
/// whose trace id matches exactly one coordinator `span.embed`: the
/// id is minted once at the accept path and carried through the queue
/// into the batch worker.
#[test]
fn trace_ids_tie_http_requests_to_embed_spans() {
    let (svc, server, target) = start();
    let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
    let body = embed_body(2);
    for _ in 0..5 {
        let resp = conn
            .request("POST", "/embed", body.as_bytes())
            .unwrap();
        assert_eq!(resp.status, 200);
    }

    let obs = svc.handle().obs();
    let http_ids: BTreeSet<u64> = obs
        .events_named("http.request")
        .iter()
        .filter(|e| {
            e.prop("route").and_then(|v| v.as_str())
                == Some("POST /embed")
        })
        .map(Event::trace_id)
        .collect();
    let span_ids: BTreeSet<u64> = obs
        .events_named("span.embed")
        .iter()
        .map(Event::trace_id)
        .collect();
    assert_eq!(http_ids.len(), 5, "five distinct request traces");
    assert!(!http_ids.contains(&0), "trace ids must be non-zero");
    assert_eq!(
        http_ids, span_ids,
        "HTTP roots and embed spans must pair one-to-one"
    );

    server.shutdown();
    svc.shutdown();
}

/// `[obs] metrics = false` turns the endpoint off (404) without
/// disturbing the serving path.
#[test]
fn metrics_endpoint_is_gated_by_config() {
    let registry = Arc::new(ModelRegistry::new());
    registry.publish(DEFAULT_MODEL, test_model());
    let obs = Arc::new(
        Obs::new(&ObsConfig {
            metrics: false,
            ..Default::default()
        })
        .unwrap(),
    );
    let svc = serve_registry_obs(
        registry,
        DEFAULT_MODEL,
        native(),
        ServiceConfig::default(),
        obs,
    )
    .unwrap();
    let cfg = ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        ..Default::default()
    };
    let server = HttpServer::start(svc.handle(), &cfg).unwrap();
    let target = server.local_addr().to_string();

    let mut conn = ClientConn::connect(&target, CONNECT).unwrap();
    let resp = conn.request("GET", "/metrics", b"").unwrap();
    assert_eq!(resp.status, 404);
    let body = embed_body(2);
    let resp = conn
        .request("POST", "/embed", body.as_bytes())
        .unwrap();
    assert_eq!(resp.status, 200, "serving path unaffected");

    server.shutdown();
    svc.shutdown();
}

/// Release-gated overhead bound: a hot-path record (stage histogram)
/// plus a ring emit must stay well under a microsecond each — the
/// facade is atomics and a fixed-size ring slot, never a lock or an
/// allocation.  Debug builds skip: unoptimized atomics are not what
/// production pays.
#[test]
fn obs_hot_path_overhead_release_gate() {
    if cfg!(debug_assertions) {
        return;
    }
    let obs = Obs::default();
    const N: u32 = 100_000;
    let t0 = Instant::now();
    for i in 0..N {
        obs.hub.queue_wait_us.record(f64::from(i % 1000));
        obs.emit(
            Event::new("bench.tick")
                .trace(u64::from(i) + 1)
                .with("i", u64::from(i)),
        );
    }
    let per_op_ns =
        t0.elapsed().as_nanos() as f64 / f64::from(N);
    assert!(
        per_op_ns < 5_000.0,
        "record+emit cost {per_op_ns:.0} ns — the obs hot path has \
         stopped being allocation-free"
    );
    assert_eq!(obs.hub.queue_wait_us.snapshot().count, u64::from(N));
}
