//! End-to-end mixed-precision serving contract, exercised strictly
//! through the public API:
//!
//! * a model published at f32 precision serves embeddings whose
//!   relative error against the exact f64 path stays within the bound
//!   the publish-time probe reported (and that bound itself is tiny
//!   for a Gaussian kernel with the default accumulate-in-f64 policy);
//! * the f32 serving scratch is allocation-free at steady state;
//! * quantization is deterministic across save/load, so a model file
//!   round-trip reproduces the recorded diagnostic bit for bit;
//! * the f32 path is bitwise invariant to the compute-thread count.

use rskpca::config::ServiceConfig;
use rskpca::coordinator::{
    EmbeddingService, ModelRegistry, DEFAULT_MODEL,
};
use rskpca::data::gaussian_mixture_2d;
use rskpca::kernel::{Accum, F32Operands, Kernel, ScratchF32};
use rskpca::kpca::{fit_kpca, EmbeddingModel, Precision};
use rskpca::linalg::Matrix;
use rskpca::runtime::{BackendFactory, NativeBackend};
use std::sync::{Arc, Mutex};

/// Serializes the test that flips the process-global thread count
/// (mirrors the lock `tests/parallel_consistency.rs` keeps).
static THREAD_LOCK: Mutex<()> = Mutex::new(());

fn fitted_model() -> (EmbeddingModel, Matrix) {
    let ds = gaussian_mixture_2d(120, 3, 0.45, 7);
    let model = fit_kpca(&ds.x, &Kernel::gaussian(1.0), 5).unwrap();
    (model, ds.x)
}

fn native() -> BackendFactory {
    Box::new(|| Ok(Box::new(NativeBackend::new())))
}

/// Max per-row relative L2 error of `got` against `want`.
fn max_rel_err(want: &Matrix, got: &Matrix) -> f64 {
    assert_eq!((want.rows(), want.cols()), (got.rows(), got.cols()));
    let mut worst = 0.0f64;
    for i in 0..want.rows() {
        let (w, g) = (want.row(i), got.row(i));
        let num = w
            .iter()
            .zip(g)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        let den =
            w.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-30);
        worst = worst.max(num / den);
    }
    worst
}

#[test]
fn f32_publish_serves_within_the_reported_bound() {
    let (model, x) = fitted_model();
    let exact = model.transform(&x);

    let registry = Arc::new(ModelRegistry::new());
    registry.set_serving_precision(Precision::F32);
    registry.publish(DEFAULT_MODEL, model);
    let published = registry.get(DEFAULT_MODEL).unwrap();
    assert_eq!(published.precision(), Precision::F32);
    let err = published.quant_error().expect("probe error recorded");
    // Acceptance bound: Gaussian kernel + accumulate-in-f64 keeps the
    // probe-block error at the f32 quantization floor.
    assert!(
        err.max_rel <= 1e-5,
        "probe max_rel {:.3e} above 1e-5",
        err.max_rel
    );
    assert!(err.mean_rel <= err.max_rel);

    let svc = EmbeddingService::start_with_registry(
        registry,
        DEFAULT_MODEL,
        native(),
        ServiceConfig::default(),
    )
    .unwrap();
    let got = svc.handle().embed(x.clone()).unwrap();
    // Served rows are fresh (not the probe block): allow an order of
    // magnitude of slack over the reported bound.
    let worst = max_rel_err(&exact, &got);
    assert!(
        worst <= (err.max_rel * 10.0).max(1e-6),
        "served rel err {worst:.3e} vs reported bound {:.3e}",
        err.max_rel
    );
    let snap = svc.shutdown();
    assert_eq!(snap.model_precision, Precision::F32);
    assert_eq!(snap.model_quant, Some(err));
}

#[test]
fn f32_scratch_is_allocation_free_at_steady_state() {
    let (model, x) = fitted_model();
    let kernel = model.kernel;
    let ops = F32Operands::quantize(
        &model.centers,
        &model.coeffs,
        Accum::F64,
    );
    let mut scratch = ScratchF32::new();
    let first = kernel.embed_rows_f32_with(&mut scratch, &x, &ops).unwrap();
    let warm = scratch.grow_events();
    assert!(warm > 0, "warmup must have grown the buffers");
    for _ in 0..5 {
        let again =
            kernel.embed_rows_f32_with(&mut scratch, &x, &ops).unwrap();
        // Steady state: bitwise-stable output, zero further growth.
        assert_eq!(again, first);
        assert_eq!(scratch.grow_events(), warm);
    }
    // A smaller batch fits the warmed buffers too.
    let idx: Vec<usize> = (0..10).collect();
    let small = x.select_rows(&idx);
    let _ = kernel.embed_rows_f32_with(&mut scratch, &small, &ops).unwrap();
    assert_eq!(scratch.grow_events(), warm);
}

#[test]
fn quantization_is_deterministic_across_model_file_roundtrip() {
    let (mut model, x) = fitted_model();
    let err = model.quantize_for_serving().unwrap();
    let path = std::env::temp_dir().join("rskpca_mixed_precision.json");
    model.save(&path).unwrap();
    let loaded = EmbeddingModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    // The file stores only f64 operands + the precision tag; loading
    // re-quantizes deterministically, reproducing the exact diagnostic.
    assert_eq!(loaded.precision(), Precision::F32);
    assert_eq!(loaded.quant_error(), Some(err));
    let mut scratch = ScratchF32::new();
    let a = model.transform_batch_f32_with(&mut scratch, &x);
    let b = loaded.transform_batch_f32_with(&mut scratch, &x);
    assert_eq!(a, b, "re-quantized serving must be bitwise identical");
}

#[test]
fn f32_embedding_is_bitwise_thread_invariant() {
    let _g = THREAD_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let (mut model, x) = fitted_model();
    model.quantize_for_serving().unwrap();
    rskpca::parallel::set_threads(1);
    let mut s1 = ScratchF32::new();
    let z1 = model.transform_batch_f32_with(&mut s1, &x);
    for t in [2usize, 4, 8] {
        rskpca::parallel::set_threads(t);
        let mut st = ScratchF32::new();
        let zt = model.transform_batch_f32_with(&mut st, &x);
        assert_eq!(z1, zt, "thread count {t} changed the f32 embedding");
    }
    rskpca::parallel::set_threads(0);
}
