//! Micro benches over the hot paths: symmetric eigensolver, packed
//! GEMM vs the naive serial reference, the distance-free (norm-trick)
//! Gram vs the naive pair-by-pair reference, fused batched projection,
//! PJRT gram/embed (when artifacts exist), and the end-to-end service
//! throughput — the inputs to EXPERIMENTS.md §Perf.
//!
//! Besides stdout and `bench_micro.csv`, the run emits the
//! machine-readable `BENCH_MICRO.json` at the repo root (op, n/m/d,
//! threads, ns/op, rows/s) so the perf trajectory is tracked across PRs.

use std::path::Path;

use rskpca::bench::{harness, BenchMeta};
use rskpca::config::ServiceConfig;
use rskpca::coordinator::serve;
use rskpca::data::gaussian_mixture_2d;
use rskpca::kernel::Kernel;
use rskpca::kpca::fit_kpca;
use rskpca::linalg::{eigh, eigh_serial, subspace_eigh, Matrix};
use rskpca::parallel;
use rskpca::prng::Pcg64;
use rskpca::runtime::{factory_from_name, GramBackend, NativeBackend, PjrtBackend};

fn random(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed);
    let mut m = Matrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.set(i, j, rng.normal());
        }
    }
    m
}

fn main() {
    let mut b = harness();
    let quick = rskpca::bench::quick_mode();

    // Symmetric eigensolver scaling: blocked production solve vs the
    // retained serial tred2/tql2 reference vs parallel top-k subspace
    // iteration (the full sweep lives in `rskpca bench eigen`).
    for &n in if quick { &[64usize, 128][..] } else { &[64, 128, 256, 512][..] } {
        let x = random(n, n, 1);
        let sym = x.matmul_transb(&x).unwrap().scale(1.0 / n as f64);
        b.bench(&format!("eigh/n{n}"), || {
            eigh(&sym).unwrap().values[0]
        });
        b.bench(&format!("eigh_serial/n{n}"), || {
            eigh_serial(&sym).unwrap().values[0]
        });
        b.bench(&format!("subspace_eigh/k8/n{n}"), || {
            subspace_eigh(&sym, 8, 200, 1e-10).unwrap().values[0]
        });
    }

    // Packed GEMM vs the naive serial triple loop.
    let n_mm = if quick { 256 } else { 512 };
    {
        let a = random(n_mm, n_mm, 7);
        let bm = random(n_mm, n_mm, 8);
        parallel::set_threads(1);
        let naive_mean = b
            .bench_meta(
                &format!("matmul_serial/n{n_mm}"),
                BenchMeta::new("gemm_serial", n_mm, n_mm, n_mm, 1),
                n_mm as f64,
                || a.matmul_serial(&bm).unwrap().rows(),
            )
            .mean_s;
        let gemm_1t = b
            .bench_meta(
                &format!("matmul_gemm/t1/n{n_mm}"),
                BenchMeta::new("gemm", n_mm, n_mm, n_mm, 1),
                n_mm as f64,
                || a.matmul(&bm).unwrap().rows(),
            )
            .mean_s;
        for &t in &[2usize, 4, 8] {
            parallel::set_threads(t);
            b.bench_meta(
                &format!("matmul_gemm/t{t}/n{n_mm}"),
                BenchMeta::new("gemm", n_mm, n_mm, n_mm, t),
                n_mm as f64,
                || a.matmul(&bm).unwrap().rows(),
            );
        }
        parallel::set_threads(0);
        println!(
            "# gemm n={n_mm}: packed micro-kernel 1-thread speedup \
             {:.2}x vs naive serial",
            naive_mean / gemm_1t
        );
    }

    // Norm-trick vs naive serial symmetric Gram — the tentpole
    // acceptance check: >= 3x single-thread at n=2000, d=64 over the
    // retained serial reference, scaling across threads {2,4,8}, and
    // <= 1e-10 agreement everywhere.
    let kernel = Kernel::gaussian(1.0);
    let n_sym = if quick { 512 } else { 2000 };
    let d_sym = 64;
    let xs = random(n_sym, d_sym, 9);
    let serial_mean = b
        .bench_meta(
            &format!("gram_sym_serial/n{n_sym}"),
            BenchMeta::new("gram_sym_serial", n_sym, n_sym, d_sym, 1),
            n_sym as f64,
            || kernel.gram_sym_serial(&xs).rows(),
        )
        .mean_s;
    let mut speedup_1t = 0.0;
    let mut speedup_4t = 0.0;
    for &t in &[1usize, 2, 4, 8] {
        parallel::set_threads(t);
        let mean = b
            .bench_meta(
                &format!("gram_sym/t{t}/n{n_sym}"),
                BenchMeta::new("gram_sym", n_sym, n_sym, d_sym, t),
                n_sym as f64,
                || kernel.gram_sym(&xs).rows(),
            )
            .mean_s;
        if t == 1 {
            speedup_1t = serial_mean / mean;
        }
        if t == 4 {
            speedup_4t = serial_mean / mean;
        }
    }
    parallel::set_threads(0);
    let dev = kernel
        .gram_sym(&xs)
        .sub(&kernel.gram_sym_serial(&xs))
        .unwrap()
        .max_abs();
    println!(
        "# gram_sym n={n_sym} d={d_sym}: norm-trick GEMM speedup \
         {speedup_1t:.2}x (1 thread) / {speedup_4t:.2}x (4 threads) vs \
         naive serial; max |fast - serial| = {dev:.3e}"
    );

    // Native gram (asymmetric norm-trick path, through the backend).
    let kernel = Kernel::gaussian(1.0);
    for &(n, m, d) in if quick {
        &[(256usize, 128usize, 32usize)][..]
    } else {
        &[(256, 128, 32), (1024, 512, 32), (1024, 512, 256)][..]
    } {
        let x = random(n, d, 2);
        let y = random(m, d, 3);
        let mut native = NativeBackend::new();
        b.bench_meta(
            &format!("gram_native/{n}x{m}x{d}"),
            BenchMeta::new("gram", n, m, d, 0),
            (n * m) as f64,
            || native.gram(&x, &y, &kernel).unwrap().rows(),
        );
    }

    // PJRT gram/embed (artifact path), if built.  load() also fails in
    // stub builds (no `pjrt` feature) even when artifacts exist — skip,
    // don't panic.
    match if Path::new("artifacts/manifest.json").exists() {
        PjrtBackend::load(Path::new("artifacts")).map(Some)
    } else {
        Ok(None)
    } {
        Ok(Some(mut pjrt)) => {
            for &(n, m, d) in if quick {
                &[(256usize, 128usize, 32usize)][..]
            } else {
                &[(256, 128, 32), (1024, 512, 32), (1024, 512, 256)][..]
            } {
                let x = random(n, d, 2);
                let y = random(m, d, 3);
                b.bench_throughput(
                    &format!("gram_pjrt/{n}x{m}x{d}"),
                    (n * m) as f64,
                    || pjrt.gram(&x, &y, &kernel).unwrap().rows(),
                );
                let a = random(m, 5, 4).scale(0.2);
                b.bench_throughput(
                    &format!("embed_pjrt/{n}x{m}x{d}k5"),
                    n as f64,
                    || pjrt.embed(&x, &y, &a, &kernel).unwrap().rows(),
                );
            }
        }
        Ok(None) => println!("# artifacts missing: skipping PJRT benches"),
        Err(e) => println!("# pjrt unavailable ({e}): skipping PJRT benches"),
    }

    // Shadow selection.
    let big = gaussian_mixture_2d(if quick { 500 } else { 4000 }, 4, 0.3, 5);
    let sd = rskpca::density::ShadowDensity::new(4.0);
    use rskpca::density::RsdeEstimator;
    b.bench_throughput("shadow_select", big.n() as f64, || {
        sd.reduce(&big.x, &kernel).m()
    });

    // Service round-trip (native backend, batched).
    let ds = gaussian_mixture_2d(400, 3, 0.4, 6);
    let model = fit_kpca(&ds.x, &kernel, 4).unwrap();

    // Batched projection through the fused norm-trick path, 1 thread vs
    // auto.
    parallel::set_threads(1);
    let tb_serial = b
        .bench_meta(
            "transform_batch/t1/400x400",
            BenchMeta::new("embed", 400, 400, 2, 1),
            400.0,
            || model.transform_batch(&ds.x).rows(),
        )
        .mean_s;
    parallel::set_threads(0);
    let tb_auto = b
        .bench_meta(
            "transform_batch/auto/400x400",
            BenchMeta::new("embed", 400, 400, 2, 0),
            400.0,
            || model.transform_batch(&ds.x).rows(),
        )
        .mean_s;
    println!(
        "# transform_batch 400x400: auto-thread speedup {:.2}x",
        tb_serial / tb_auto
    );

    let svc = serve(
        model,
        factory_from_name("native", Path::new("artifacts")),
        ServiceConfig { max_batch: 128, max_wait_us: 100, ..Default::default() },
    )
    .unwrap();
    let h = svc.handle();
    let probe = ds.x.select_rows(&(0..16).collect::<Vec<_>>());
    b.bench_meta(
        "service_roundtrip/16rows",
        BenchMeta::new("service", 16, 400, 2, 0),
        16.0,
        || h.embed(probe.clone()).unwrap().rows(),
    );
    drop(svc);
    b.write_csv(std::path::Path::new("bench_micro.csv")).ok();
    // Machine-readable artifact at the repo root (the bench runs with
    // the crate dir as cwd; the manifest dir pins it regardless).
    let json_path =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_MICRO.json");
    match b.write_json(&json_path) {
        Ok(()) => println!("# wrote {}", json_path.display()),
        Err(e) => println!("# could not write BENCH_MICRO.json: {e}"),
    }
}
