//! Micro benches over the hot paths: symmetric eigensolver, native Gram,
//! PJRT gram/embed (when artifacts exist), and the end-to-end service
//! throughput — the inputs to EXPERIMENTS.md §Perf.

use std::path::Path;

use rskpca::bench::harness;
use rskpca::config::ServiceConfig;
use rskpca::coordinator::serve;
use rskpca::data::gaussian_mixture_2d;
use rskpca::kernel::Kernel;
use rskpca::kpca::fit_kpca;
use rskpca::linalg::{eigh, Matrix};
use rskpca::prng::Pcg64;
use rskpca::runtime::{factory_from_name, GramBackend, NativeBackend, PjrtBackend};

fn random(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed);
    let mut m = Matrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            m.set(i, j, rng.normal());
        }
    }
    m
}

fn main() {
    let mut b = harness();
    let quick = rskpca::bench::quick_mode();

    // Symmetric eigensolver scaling.
    for &n in if quick { &[64usize, 128][..] } else { &[64, 128, 256, 512][..] } {
        let x = random(n, n, 1);
        let sym = x.matmul_transb(&x).unwrap().scale(1.0 / n as f64);
        b.bench(&format!("eigh/n{n}"), || {
            eigh(&sym).unwrap().values[0]
        });
    }

    // Native gram.
    let kernel = Kernel::gaussian(1.0);
    for &(n, m, d) in if quick {
        &[(256usize, 128usize, 32usize)][..]
    } else {
        &[(256, 128, 32), (1024, 512, 32), (1024, 512, 256)][..]
    } {
        let x = random(n, d, 2);
        let y = random(m, d, 3);
        let mut native = NativeBackend;
        b.bench_throughput(
            &format!("gram_native/{n}x{m}x{d}"),
            (n * m) as f64,
            || native.gram(&x, &y, &kernel).unwrap().rows(),
        );
    }

    // PJRT gram/embed (artifact path), if built.
    if Path::new("artifacts/manifest.json").exists() {
        let mut pjrt = PjrtBackend::load(Path::new("artifacts")).unwrap();
        for &(n, m, d) in if quick {
            &[(256usize, 128usize, 32usize)][..]
        } else {
            &[(256, 128, 32), (1024, 512, 32), (1024, 512, 256)][..]
        } {
            let x = random(n, d, 2);
            let y = random(m, d, 3);
            b.bench_throughput(
                &format!("gram_pjrt/{n}x{m}x{d}"),
                (n * m) as f64,
                || pjrt.gram(&x, &y, &kernel).unwrap().rows(),
            );
            let a = random(m, 5, 4).scale(0.2);
            b.bench_throughput(
                &format!("embed_pjrt/{n}x{m}x{d}k5"),
                n as f64,
                || pjrt.embed(&x, &y, &a, &kernel).unwrap().rows(),
            );
        }
    } else {
        println!("# artifacts missing: skipping PJRT benches");
    }

    // Shadow selection.
    let big = gaussian_mixture_2d(if quick { 500 } else { 4000 }, 4, 0.3, 5);
    let sd = rskpca::density::ShadowDensity::new(4.0);
    use rskpca::density::RsdeEstimator;
    b.bench_throughput("shadow_select", big.n() as f64, || {
        sd.reduce(&big.x, &kernel).m()
    });

    // Service round-trip (native backend, batched).
    let ds = gaussian_mixture_2d(400, 3, 0.4, 6);
    let model = fit_kpca(&ds.x, &kernel, 4).unwrap();
    let svc = serve(
        model,
        factory_from_name("native", Path::new("artifacts")),
        ServiceConfig { max_batch: 128, max_wait_us: 100, ..Default::default() },
    )
    .unwrap();
    let h = svc.handle();
    let probe = ds.x.select_rows(&(0..16).collect::<Vec<_>>());
    b.bench_throughput("service_roundtrip/16rows", 16.0, || {
        h.embed(probe.clone()).unwrap().rows()
    });
    drop(svc);
    b.write_csv(std::path::Path::new("bench_micro.csv")).ok();
}
