//! Bench for Figs. 4–5 (classification): the three pipeline stages —
//! embedding-model fit, train/test embedding, k-NN prediction — on the
//! usps-like dataset, KPCA versus ShDE+RSKPCA.

use rskpca::bench::harness;
use rskpca::classify::KnnClassifier;
use rskpca::data::train_test_split;
use rskpca::experiments::{dataset_by_name, fit_method, sigma_for, Method};
use rskpca::kernel::Kernel;

fn main() {
    let mut b = harness();
    let scale = if rskpca::bench::quick_mode() { 0.05 } else { 0.15 };
    let ds = dataset_by_name("usps", scale, 42).unwrap();
    let (train, test) = train_test_split(&ds, 0.9, 3);
    let kernel = Kernel::gaussian(sigma_for(&ds));
    let r = 15;
    println!(
        "# fig4/5 bench: usps train={} test={} d={} r={r}",
        train.n(),
        test.n(),
        train.dim()
    );

    for method in [Method::Kpca, Method::Shde, Method::WNystrom] {
        b.bench(&format!("fit/{}", method.name()), || {
            fit_method(method, &train.x, &kernel, r, 60, 4.0, 1)
                .unwrap()
                .m
        });
    }
    for method in [Method::Kpca, Method::Shde] {
        let fitted =
            fit_method(method, &train.x, &kernel, r, 60, 4.0, 1).unwrap();
        let z_train = fitted.model.transform(&train.x);
        let z_test = fitted.model.transform(&test.x);
        b.bench_throughput(
            &format!("embed_test/{}", method.name()),
            test.n() as f64,
            || fitted.model.transform(&test.x).rows(),
        );
        let knn = KnnClassifier::fit(z_train, train.y.clone(), 3);
        b.bench_throughput(
            &format!("knn_predict/{}", method.name()),
            test.n() as f64,
            || knn.predict(&z_test).len(),
        );
    }
    b.write_csv(std::path::Path::new("bench_classification.csv")).ok();
}
