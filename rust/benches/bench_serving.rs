//! Loopback end-to-end serving bench: HTTP ingress → coordinator
//! queue → dynamic batcher → native backend, measured with the
//! closed-loop load generator across model kinds (full KPCA vs RSKPCA
//! at m ∈ {100, 400}) and HTTP worker counts {1, 4}.
//!
//! The punchline row set is the paper's serving claim made concrete:
//! RSKPCA evaluates m ≪ n kernels per projected row, so at equal
//! traffic the reduced-set models clear more rows/s at lower p99 than
//! the full-KPCA model whose centers are the whole training set — the
//! reduced-set serving speedup printed at the end.
//!
//! Run: `cargo bench --bench bench_serving`
//! (quick: `RSKPCA_BENCH_QUICK=1 cargo bench --bench bench_serving`)
//!
//! Besides stdout and `bench_serving.csv`, the run emits the
//! machine-readable `BENCH_SERVING.json` at the repo root (model, op,
//! centers, http workers, rows/s, latency percentiles) so serving perf
//! is tracked across PRs.
//!
//! A final `serving/batch1_dispatch/{pool,spawn}` row pair times one
//! 8-part parallel fan-out with a trivial body through the persistent
//! worker pool vs the per-call spawn fallback (ns/op) — the dispatch
//! overhead every served batch pays, isolated from compute.

use rskpca::bench::quick_mode;
use rskpca::ser::Json;
use rskpca::config::{ServerConfig, ServiceConfig};
use rskpca::coordinator::EmbeddingService;
use rskpca::data::gaussian_mixture_2d;
use rskpca::density::ShadowDensity;
use rskpca::kernel::Kernel;
use rskpca::kpca::{fit_kpca, fit_rskpca, EmbeddingModel};
use rskpca::linalg::Matrix;
use rskpca::prng::Pcg64;
use rskpca::runtime::{BackendFactory, NativeBackend};
use rskpca::server::loadgen::{self, LoadgenConfig};
use rskpca::server::HttpServer;

/// n points jittered (±0.05 per coordinate) around m grid sites spaced
/// 1.0 apart; with eps = sigma/ell = 0.25 the shadow cover retains
/// exactly m centers, pinning the reduced-set size the serving cost
/// scales with.
fn grid_points(m: usize, n: usize, seed: u64) -> Matrix {
    let side = (m as f64).sqrt().ceil() as usize;
    let mut rng = Pcg64::new(seed);
    let mut x = Matrix::zeros(n, 2);
    for i in 0..n {
        let site = if i < m { i } else { rng.below(m) };
        let (r, c) = (site / side, site % side);
        x.set(i, 0, r as f64 + rng.range(-0.05, 0.05));
        x.set(i, 1, c as f64 + rng.range(-0.05, 0.05));
    }
    x
}

fn native() -> BackendFactory {
    Box::new(|| Ok(Box::new(NativeBackend::new())))
}

fn main() {
    let quick = quick_mode();
    let rank = 8;
    let kernel = Kernel::gaussian(1.0);
    let n_full = if quick { 300 } else { 1000 };
    let (clients, requests_per_client) =
        if quick { (2, 25) } else { (4, 120) };
    let rows_per_request = 8;

    // Full KPCA: every training point is a serving center (the O(rn)
    // per-point test cost the paper attacks).
    let ds = gaussian_mixture_2d(n_full, 3, 0.5, 11);
    let full = fit_kpca(&ds.x, &kernel, rank).unwrap();

    // RSKPCA at pinned reduced-set sizes m ∈ {100, 400}.
    let mut base_models: Vec<(String, EmbeddingModel)> =
        vec![(format!("full_n{n_full}"), full)];
    for m in [100usize, 400] {
        let x = grid_points(m, 4 * m, 29 + m as u64);
        let rs = ShadowDensity::new(4.0).fit(&x, &kernel);
        let model = fit_rskpca(&rs, &kernel, rank).unwrap();
        base_models
            .push((format!("rskpca_m{}", model.n_retained()), model));
    }
    // Each model also runs as its f32-published twin: same operands,
    // quantized at publish time and served through the f32 micro-kernel
    // path — the mixed-precision serving speedup measured end to end.
    let mut models: Vec<(String, EmbeddingModel)> = Vec::new();
    for (name, model) in base_models {
        let mut f32_twin = model.clone();
        let qerr = f32_twin.quantize_for_serving().unwrap();
        println!(
            "{name}: f32 probe quantization error max_rel={:.3e} \
             mean_rel={:.3e}",
            qerr.max_rel, qerr.mean_rel
        );
        models.push((name.clone(), model));
        models.push((format!("{name}_f32"), f32_twin));
    }

    println!(
        "bench_serving: loopback HTTP end-to-end ({clients} clients x \
         {requests_per_client} requests x {rows_per_request} rows)\n"
    );
    let mut csv = String::from(
        "model,centers,http_workers,rows_per_s,p50_us,p95_us,p99_us,\
         ok,rejected,errors\n",
    );
    // (model name, workers, rows/s) for the speedup summary.
    let mut results: Vec<(String, usize, f64)> = Vec::new();
    // Machine-readable rows for BENCH_SERVING.json.
    let mut json_rows: Vec<Json> = Vec::new();

    for (name, model) in &models {
        for &workers in &[1usize, 4] {
            let svc = EmbeddingService::start(
                model.clone(),
                native(),
                ServiceConfig::default(),
            )
            .unwrap();
            let server_cfg = ServerConfig {
                listen: "127.0.0.1:0".into(),
                workers,
                ..Default::default()
            };
            let server =
                HttpServer::start(svc.handle(), &server_cfg).unwrap();
            let mut report = loadgen::run(&LoadgenConfig {
                target: server.local_addr().to_string(),
                clients,
                requests_per_client,
                rows_per_request,
                dim: 0,
                seed: 0xBE_EF,
                warmup_ms: 3000,
                rate: 0.0,
                metrics_poll_s: 0,
                retry: false,
            })
            .unwrap();
            let label = format!("serving/{name}/w{workers}");
            println!(
                "{label:<34} {:>9.0} rows/s  p50 {:>7.0}us  \
                 p95 {:>7.0}us  p99 {:>7.0}us  ({} ok, {} rejected, \
                 {} errors)",
                report.rows_per_s(),
                report.latency_us.percentile(50.0),
                report.latency_us.percentile(95.0),
                report.latency_us.p99(),
                report.requests_ok,
                report.rejected,
                report.errors
            );
            csv.push_str(&format!(
                "{name},{},{workers},{:.1},{:.1},{:.1},{:.1},{},{},{}\n",
                model.n_retained(),
                report.rows_per_s(),
                report.latency_us.percentile(50.0),
                report.latency_us.percentile(95.0),
                report.latency_us.p99(),
                report.requests_ok,
                report.rejected,
                report.errors
            ));
            results.push((name.clone(), workers, report.rows_per_s()));
            json_rows.push(
                Json::obj()
                    .with("name", Json::Str(label.clone()))
                    .with("op", Json::Str("serving".into()))
                    .with("model", Json::Str(name.clone()))
                    .with(
                        "precision",
                        Json::Str(model.precision().name().into()),
                    )
                    .with(
                        "n",
                        Json::Num(
                            (clients * requests_per_client
                                * rows_per_request)
                                as f64,
                        ),
                    )
                    .with("m", Json::Num(model.n_retained() as f64))
                    .with("d", Json::Num(2.0))
                    .with("threads", Json::Num(workers as f64))
                    .with(
                        "rows_per_s",
                        Json::Num(report.rows_per_s()),
                    )
                    .with(
                        "p50_us",
                        Json::Num(report.latency_us.percentile(50.0)),
                    )
                    .with(
                        "p95_us",
                        Json::Num(report.latency_us.percentile(95.0)),
                    )
                    .with("p99_us", Json::Num(report.latency_us.p99()))
                    .with(
                        "ok",
                        Json::Num(report.requests_ok as f64),
                    )
                    .with("rejected", Json::Num(report.rejected as f64))
                    .with("errors", Json::Num(report.errors as f64)),
            );
            server.shutdown();
            svc.shutdown();
        }
    }

    // The paper's serving claim, measured end to end over the wire.
    let rate = |name: &str, workers: usize| -> f64 {
        results
            .iter()
            .find(|(n, w, _)| n == name && *w == workers)
            .map(|(_, _, r)| *r)
            .unwrap_or(0.0)
    };
    let full_name = format!("full_n{n_full}");
    println!();
    for (name, _) in &models {
        if name == &full_name || name.ends_with("_f32") {
            continue;
        }
        let base = rate(&full_name, 4).max(1e-9);
        println!(
            "reduced-set serving speedup {name} vs {full_name} \
             (4 http workers): {:.2}x",
            rate(name, 4) / base
        );
    }
    // The mixed-precision serving claim: f32-published twin vs its f64
    // original at equal traffic.
    for (name, _) in &models {
        let Some(base) = name.strip_suffix("_f32") else {
            continue;
        };
        let f64_rate = rate(base, 4).max(1e-9);
        println!(
            "f32 serving speedup {name} vs {base} (4 http workers): \
             {:.2}x",
            rate(name, 4) / f64_rate
        );
    }
    // Batch-size-1 dispatch latency: the serving hot path pays one
    // parallel fan-out per executed batch, so the spawn-vs-wake win is
    // isolated here — an 8-part dispatch with a trivial body, timed
    // through the persistent pool and then with the per-call
    // scoped-spawn fallback forced.  The compute is nil by design; the
    // difference IS the dispatch overhead.
    rskpca::parallel::set_threads(8);
    let ranges = rskpca::parallel::even_ranges(8, 8);
    let iters = if quick { 2_000usize } else { 20_000 };
    let dispatch_ns = |iters: usize| -> f64 {
        for _ in 0..100 {
            std::hint::black_box(rskpca::parallel::par_map_parts(
                &ranges,
                |_, r| r.start,
            ));
        }
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            std::hint::black_box(rskpca::parallel::par_map_parts(
                &ranges,
                |_, r| r.start,
            ));
        }
        t0.elapsed().as_secs_f64() * 1e9 / iters as f64
    };
    let pool_ns = dispatch_ns(iters);
    rskpca::parallel::force_spawn_fallback(true);
    let spawn_ns = dispatch_ns(iters);
    rskpca::parallel::force_spawn_fallback(false);
    rskpca::parallel::set_threads(0);
    println!(
        "\nbatch-1 dispatch (8 parts, trivial body): pool {pool_ns:.0} \
         ns/op vs spawn {spawn_ns:.0} ns/op ({:.1}x)",
        spawn_ns / pool_ns.max(1e-9)
    );
    for (variant, ns) in
        [("pool", pool_ns), ("spawn", spawn_ns)]
    {
        json_rows.push(
            Json::obj()
                .with(
                    "name",
                    Json::Str(format!(
                        "serving/batch1_dispatch/{variant}"
                    )),
                )
                .with("op", Json::Str("dispatch".into()))
                .with("model", Json::Str(variant.into()))
                .with("n", Json::Num(1.0))
                .with("m", Json::Num(8.0))
                .with("d", Json::Num(0.0))
                .with("threads", Json::Num(8.0))
                .with("ns_per_op", Json::Num(ns)),
        );
    }
    std::fs::write("bench_serving.csv", csv)
        .expect("write bench_serving.csv");
    let json_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../BENCH_SERVING.json");
    std::fs::write(&json_path, Json::Arr(json_rows).to_string())
        .expect("write BENCH_SERVING.json");
    println!(
        "\nwrote bench_serving.csv and {}",
        json_path.display()
    );
}
