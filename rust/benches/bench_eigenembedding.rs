//! Bench for Figs. 2–3 (eigenembedding): per-method fit and embed cost on
//! the german-like dataset at matched m, the end-to-end pieces the
//! figures' speedup panels measure.
//!
//! `cargo bench --bench bench_eigenembedding` (RSKPCA_BENCH_QUICK=1 for a
//! fast pass).

use rskpca::bench::harness;
use rskpca::experiments::{
    dataset_by_name, fit_method, sigma_for, Method,
};
use rskpca::kernel::Kernel;

fn main() {
    let mut b = harness();
    let scale = if rskpca::bench::quick_mode() { 0.2 } else { 0.8 };
    let ds = dataset_by_name("german", scale, 42).unwrap();
    let kernel = Kernel::gaussian(sigma_for(&ds));
    let r = 5;
    // Matched m from ShDE at ell = 4.
    let shde =
        fit_method(Method::Shde, &ds.x, &kernel, r, 0, 4.0, 1).unwrap();
    let m = shde.m;
    println!(
        "# fig2/3 bench: german n={} d={} m={m} r={r}",
        ds.n(),
        ds.dim()
    );

    for method in [
        Method::Kpca,
        Method::Shde,
        Method::Subsample,
        Method::Nystrom,
        Method::WNystrom,
    ] {
        b.bench(&format!("fit/{}", method.name()), || {
            fit_method(method, &ds.x, &kernel, r, m, 4.0, 1).unwrap().m
        });
    }
    // Embed (test-time) cost: the figures' testing-speedup panel.
    let probe = ds.x.select_rows(&(0..200.min(ds.n())).collect::<Vec<_>>());
    for method in [Method::Kpca, Method::Shde, Method::Nystrom] {
        let fitted =
            fit_method(method, &ds.x, &kernel, r, m, 4.0, 1).unwrap();
        b.bench_throughput(
            &format!("embed200/{}", method.name()),
            200.0,
            || fitted.model.transform(&probe).rows(),
        );
    }
    b.write_csv(std::path::Path::new("bench_eigenembedding.csv")).ok();
}
