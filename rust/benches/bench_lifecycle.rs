//! Online-lifecycle bench: incremental `EmbeddingModel::refresh` versus
//! a full retrain (re-reduce all n source points + refit), at
//! m ∈ {100, 400, 1000} with n = 10·m source points, plus hot-swap
//! publish latency under concurrent `embed` load.
//!
//! The dataset is a jittered grid of exactly m ε-separated sites so the
//! streaming cover retains exactly m centers — the knob the lifecycle
//! cost model is parameterized by.  Full retrain pays O(n·m) for the
//! re-reduction plus the O(m³) exact eigensolve; refresh pays only the
//! incremental Gram update plus the m×m solve (O(m²k) under the
//! `Subspace` policy the refreshed model records) — the ≥5× gap the
//! acceptance criteria ask for at m = 1000.
//!
//! Run: `cargo bench --bench bench_lifecycle`
//! (quick: `RSKPCA_BENCH_QUICK=1 cargo bench --bench bench_lifecycle`)

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use rskpca::bench::{harness, quick_mode};
use rskpca::config::ServiceConfig;
use rskpca::coordinator::{EmbeddingService, ModelRegistry, DEFAULT_MODEL};
use rskpca::density::{RsdeEstimator, ShadowDensity, StreamingShadow};
use rskpca::kernel::Kernel;
use rskpca::kpca::{fit_rskpca, fit_rskpca_with, EigSolver, GramCache};
use rskpca::linalg::Matrix;
use rskpca::prng::Pcg64;
use rskpca::runtime::NativeBackend;

/// n points jittered (±0.05 per coordinate, so any two points of one
/// site are within 0.1·√2 < 0.25 of each other) around m grid sites
/// spaced 1.0 apart; with eps = sigma/ell = 0.25 the streaming cover
/// retains exactly m centers (every site appears at least once).
///
/// Points before `cut` use only the first `m_pre` sites; the remaining
/// `m - m_pre` sites first appear at `cut`, so the delta window the
/// refresh benchmark replays carries real center *additions* (the
/// incremental Gram-extension path), not just weight bumps.
fn grid_stream(m: usize, n: usize, cut: usize, m_pre: usize, seed: u64)
    -> Matrix {
    assert!(m_pre <= m && cut + (m - m_pre) <= n && m_pre <= cut);
    let side = (m as f64).sqrt().ceil() as usize;
    let mut rng = Pcg64::new(seed);
    let mut x = Matrix::zeros(n, 2);
    for i in 0..n {
        let site = if i < m_pre {
            i
        } else if i < cut {
            rng.below(m_pre)
        } else if i < cut + (m - m_pre) {
            m_pre + (i - cut)
        } else {
            rng.below(m)
        };
        x.set(i, 0, (site / side) as f64 + 0.05 * rng.range(-1.0, 1.0));
        x.set(i, 1, (site % side) as f64 + 0.05 * rng.range(-1.0, 1.0));
    }
    x
}

fn main() {
    let mut b = harness();
    let kernel = Kernel::gaussian(1.0); // eps = 0.25 at ell = 4
    let rank = 5;
    let sizes: &[usize] =
        if quick_mode() { &[50, 100] } else { &[100, 400, 1000] };

    for &m in sizes {
        let n = 10 * m;
        // The last 10% of the stream is the delta window; 5% of the
        // sites first appear inside it, so the refresh replays genuine
        // center additions (Gram extension) on top of weight bumps.
        let cut = n - n / 10;
        let m_pre = m - (m / 20).max(1);
        let x = grid_stream(m, n, cut, m_pre, 42);

        let mut stream = StreamingShadow::new(&kernel, 4.0, 2);
        for i in 0..cut {
            stream.observe(x.row(i));
        }
        stream.drain_delta();
        let base_exact =
            fit_rskpca(&stream.snapshot(), &kernel, rank).unwrap();
        let base_sub = fit_rskpca_with(
            &stream.snapshot(),
            &kernel,
            rank,
            &EigSolver::Subspace { k: 0, tol: 1e-10 },
        )
        .unwrap();
        let base_cache = GramCache::new(&kernel, &base_exact.centers);
        for i in cut..n {
            stream.observe(x.row(i));
        }
        let delta = stream.drain_delta();
        assert_eq!(stream.m(), m, "grid did not yield exactly m centers");
        assert_eq!(
            delta.added.rows(),
            m - m_pre,
            "delta window must introduce new centers"
        );

        // Full retrain: re-reduce all n points, refit from scratch.
        let retrain = b
            .bench(&format!("retrain_full/m{m}_n{n}"), || {
                let rs = ShadowDensity::new(4.0).reduce(&x, &kernel);
                fit_rskpca(&rs, &kernel, rank).unwrap().r()
            })
            .mean_s;

        // Incremental refresh, exact m x m solve.
        let refresh_exact = b
            .bench(&format!("refresh_exact/m{m}"), || {
                let mut model = base_exact.clone();
                let mut cache = base_cache.clone();
                model.refresh(&delta, &mut cache, rank).unwrap();
                model.meta.version
            })
            .mean_s;

        // Incremental refresh under the Subspace policy (the policy is
        // recorded in the model metadata, so refresh just follows it).
        let refresh_sub = b
            .bench(&format!("refresh_subspace/m{m}"), || {
                let mut model = base_sub.clone();
                let mut cache = base_cache.clone();
                model.refresh(&delta, &mut cache, rank).unwrap();
                model.meta.version
            })
            .mean_s;

        println!(
            "# m={m}: retrain/refresh_exact = {:.1}x, \
             retrain/refresh_subspace = {:.1}x",
            retrain / refresh_exact,
            retrain / refresh_sub
        );
    }

    // Hot-swap latency under concurrent embed load, at the largest size:
    // publish is a pointer swap under a write lock, so it should sit far
    // below a single batch execution.
    let m = *sizes.last().unwrap();
    let x = grid_stream(m, 10 * m, 9 * m, m, 7);
    let rs = ShadowDensity::new(4.0).reduce(&x, &kernel);
    let model = fit_rskpca(&rs, &kernel, rank).unwrap();
    let registry = Arc::new(ModelRegistry::new());
    registry.publish(DEFAULT_MODEL, model.clone());
    let svc = EmbeddingService::start_with_registry(
        registry.clone(),
        DEFAULT_MODEL,
        Box::new(|| Ok(Box::new(NativeBackend::new()))),
        ServiceConfig {
            max_batch: 64,
            max_wait_us: 200,
            queue_depth: 512,
            workers: 1,
        },
    )
    .unwrap();
    let running = Arc::new(AtomicBool::new(true));
    let mut clients = Vec::new();
    for c in 0..2u64 {
        let h = svc.handle();
        let running = running.clone();
        clients.push(std::thread::spawn(move || {
            let mut rng = Pcg64::new(0xC11E + c);
            while running.load(Ordering::Relaxed) {
                let mut rows = Matrix::zeros(16, 2);
                for i in 0..16 {
                    for j in 0..2 {
                        rows.set(i, j, rng.normal());
                    }
                }
                let _ = h.embed(rows);
            }
        }));
    }
    b.bench(&format!("hot_swap_publish/m{m}"), || {
        registry.publish(DEFAULT_MODEL, model.clone())
    });
    running.store(false, Ordering::Relaxed);
    for c in clients {
        c.join().unwrap();
    }
    let snap = svc.shutdown();
    println!(
        "# hot swap: worker observed {} swaps over {} batches \
         (serving v{})",
        snap.model_swaps, snap.batches, snap.model_version
    );

    b.write_csv(std::path::Path::new("bench_lifecycle.csv")).ok();
}
