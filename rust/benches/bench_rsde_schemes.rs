//! Bench for Figs. 7–8 (RSDE schemes): selection cost of each reduced-set
//! algorithm at matched m — the "center selection schemes that improve
//! accuracy are costlier than ShDE" claim, measured.

use rskpca::bench::harness;
use rskpca::density::{
    HerdingRsde, KMeansRsde, ParingRsde, RsdeEstimator, ShadowDensity,
    UniformSubsample,
};
use rskpca::experiments::{dataset_by_name, sigma_for};
use rskpca::kernel::Kernel;

fn main() {
    let mut b = harness();
    let scale = if rskpca::bench::quick_mode() { 0.05 } else { 0.15 };
    let ds = dataset_by_name("usps", scale, 42).unwrap();
    let kernel = Kernel::gaussian(sigma_for(&ds));
    let m = ShadowDensity::new(4.0).reduce(&ds.x, &kernel).m();
    println!("# fig7/8 bench: usps n={} d={} matched m={m}", ds.n(), ds.dim());

    let shde = ShadowDensity::new(4.0);
    b.bench_throughput("rsde/shde", ds.n() as f64, || {
        shde.reduce(&ds.x, &kernel).m()
    });
    let uni = UniformSubsample::new(m, 1);
    b.bench_throughput("rsde/uniform", ds.n() as f64, || {
        uni.reduce(&ds.x, &kernel).m()
    });
    let paring = ParingRsde::new(m, 1);
    b.bench_throughput("rsde/paring", ds.n() as f64, || {
        paring.reduce(&ds.x, &kernel).m()
    });
    let kmeans = KMeansRsde::new(m, 1);
    b.bench_throughput("rsde/kmeans", ds.n() as f64, || {
        kmeans.reduce(&ds.x, &kernel).m()
    });
    let herding = HerdingRsde::new(m, 1);
    b.bench_throughput("rsde/herding", ds.n() as f64, || {
        herding.reduce(&ds.x, &kernel).m()
    });
    b.write_csv(std::path::Path::new("bench_rsde_schemes.csv")).ok();
}
