//! Bench for the §5 bound calculators: the closed-form bounds are O(1);
//! the measured counterparts are O(n^2)/O(n^3) — this bench documents the
//! gap that makes the closed forms the practical tool.

use rskpca::bench::harness;
use rskpca::data::gaussian_mixture_2d;
use rskpca::density::{RsdeEstimator, ShadowDensity};
use rskpca::kernel::Kernel;
use rskpca::mmd::{
    measured_eigenvalue_diff, measured_hs_diff, mmd_reduced_set,
    thm51_mmd_bound, thm52_eigenvalue_bound, thm53_hs_bound,
};

fn main() {
    let mut b = harness();
    let n = if rskpca::bench::quick_mode() { 80 } else { 200 };
    let ds = gaussian_mixture_2d(n, 3, 0.4, 42);
    let kernel = Kernel::gaussian(1.0);
    let rs = ShadowDensity::new(4.0).reduce(&ds.x, &kernel);
    let quant = rs.quantized_dataset().unwrap();

    b.bench("bound/thm51_closed_form", || {
        thm51_mmd_bound(&kernel, 4.0)
    });
    b.bench("bound/thm52_closed_form", || {
        thm52_eigenvalue_bound(&kernel, 4.0)
    });
    b.bench("bound/thm53_closed_form", || thm53_hs_bound(&kernel, 4.0));
    b.bench(&format!("measured/mmd_n{n}"), || {
        mmd_reduced_set(&ds.x, &rs, &kernel)
    });
    b.bench(&format!("measured/hs_n{n}"), || {
        measured_hs_diff(&ds.x, &quant, &kernel).unwrap()
    });
    b.bench(&format!("measured/eig_n{n}"), || {
        measured_eigenvalue_diff(&ds.x, &quant, &kernel).unwrap()
    });
    b.write_csv(std::path::Path::new("bench_bounds.csv")).ok();
}
