//! Bench for Fig. 6 (retention): shadow-set selection cost across the
//! four datasets and the ℓ grid — Algorithm 2's O(mn) single pass is the
//! paper's training-cost advantage, so its absolute throughput matters.

use rskpca::bench::harness;
use rskpca::density::{RsdeEstimator, ShadowDensity};
use rskpca::experiments::{dataset_by_name, sigma_for};
use rskpca::kernel::Kernel;

fn main() {
    let mut b = harness();
    let scale = if rskpca::bench::quick_mode() { 0.05 } else { 0.25 };
    for name in ["german", "pendigits", "usps", "yale"] {
        let ds = dataset_by_name(name, scale, 42).unwrap();
        let kernel = Kernel::gaussian(sigma_for(&ds));
        for ell in [3.0, 4.0, 5.0] {
            let sd = ShadowDensity::new(ell);
            let m = sd.reduce(&ds.x, &kernel).m();
            b.bench_throughput(
                &format!("shadow/{name}/ell{ell} (m={m})"),
                ds.n() as f64,
                || sd.reduce(&ds.x, &kernel).m(),
            );
        }
    }
    b.write_csv(std::path::Path::new("bench_retention.csv")).ok();
}
