//! Bench for Table 2 (training cost): fit time versus n for full KPCA
//! (O(n^3)) against ShDE+RSKPCA / Nyström (O(mn + m^3)) — the scaling gap
//! the table asserts — plus a serial-vs-parallel comparison of the fit
//! path (the Gram phase fans out through `rskpca::parallel`).

use rskpca::bench::harness;
use rskpca::data::gaussian_mixture_2d;
use rskpca::experiments::{fit_method, Method};
use rskpca::kernel::Kernel;
use rskpca::parallel;

fn main() {
    let mut b = harness();
    let sizes: &[usize] = if rskpca::bench::quick_mode() {
        &[200, 400]
    } else {
        &[250, 500, 1000, 2000]
    };
    for &n in sizes {
        let ds = gaussian_mixture_2d(n, 4, 0.35, 42);
        let kernel = Kernel::gaussian(1.0);
        b.bench(&format!("fit_kpca/n{n}"), || {
            fit_method(Method::Kpca, &ds.x, &kernel, 5, 0, 4.0, 1)
                .unwrap()
                .m
        });
        b.bench(&format!("fit_shde_rskpca/n{n}"), || {
            fit_method(Method::Shde, &ds.x, &kernel, 5, 0, 4.0, 1)
                .unwrap()
                .m
        });
        b.bench(&format!("fit_nystrom/n{n}"), || {
            fit_method(Method::Nystrom, &ds.x, &kernel, 5, n / 10, 4.0, 1)
                .unwrap()
                .m
        });
    }
    // Serial vs parallel ShDE+RSKPCA fit at the largest size: the O(mn)
    // shadow sweep stays serial and the m x m gram/eigensolve is small,
    // but the density-weighted Gram and projection phases fan out — this
    // row shows how much of the reduced-set fit the engine reaches.
    let n = *sizes.last().unwrap();
    let ds = gaussian_mixture_2d(n, 4, 0.35, 43);
    let kernel = Kernel::gaussian(1.0);
    parallel::set_threads(1);
    let serial = b
        .bench(&format!("fit_shde_rskpca_t1/n{n}"), || {
            fit_method(Method::Shde, &ds.x, &kernel, 5, 0, 4.0, 1)
                .unwrap()
                .m
        })
        .mean_s;
    parallel::set_threads(0);
    let auto = b
        .bench(&format!("fit_shde_rskpca_auto/n{n}"), || {
            fit_method(Method::Shde, &ds.x, &kernel, 5, 0, 4.0, 1)
                .unwrap()
                .m
        })
        .mean_s;
    println!(
        "# fit_shde_rskpca n={n}: auto-thread speedup {:.2}x",
        serial / auto
    );
    b.write_csv(std::path::Path::new("bench_training_cost.csv")).ok();
}
